//! Marker-based watershed by priority-flood (the CPU variant).
//!
//! The paper uses OpenCV's watershed on the CPU and the Körbes kernel on the
//! GPU, noting the two "are not the same [algorithm]; hence, the results ...
//! are slightly different".  We reproduce that situation deliberately:
//!
//! * CPU (this file): sequential **priority-flood** — grow markers in order
//!   of relief height (a BinaryHeap keyed on (value, FIFO tiebreak)).
//! * "GPU" (`model.watershed`): synchronous iterative flooding inside an
//!   HLO `while` loop.
//!
//! Both produce valid tessellations of the mask into one region per marker;
//! tests compare region counts and seed ownership, not exact boundaries.
//!
//! Also provides [`regional_maxima`] + [`pre_watershed`], the CPU variant of
//! the paper's Pre-Watershed stage (distance transform + marker extraction).

use super::distance::distance_chessboard;
use super::label::bwlabel;
use super::reconstruct::reconstruct;
use super::{Conn, Gray};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(PartialEq)]
struct Item {
    value: f32,
    order: u64,
    y: u32,
    x: u32,
    label: f32,
}

impl Eq for Item {}

impl PartialOrd for Item {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Item {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; we want the *lowest* relief first.
        other
            .value
            .partial_cmp(&self.value)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.order.cmp(&self.order))
    }
}

/// Flood `relief` from `markers` restricted to `mask` (8-connected).
///
/// Returns a label image: 0 outside the mask; otherwise the marker id whose
/// flood reached the pixel first.
pub fn watershed(relief: &Gray, markers: &Gray, mask: &Gray) -> Gray {
    let (h, w) = (mask.h, mask.w);
    let mut labels = vec![0.0f32; h * w];
    let mut heap: BinaryHeap<Item> = BinaryHeap::new();
    let mut order = 0u64;
    for y in 0..h {
        for x in 0..w {
            let i = y * w + x;
            if markers.px[i] > 0.0 && mask.px[i] > 0.5 {
                labels[i] = markers.px[i];
                heap.push(Item {
                    value: relief.px[i],
                    order,
                    y: y as u32,
                    x: x as u32,
                    label: markers.px[i],
                });
                order += 1;
            }
        }
    }
    while let Some(it) = heap.pop() {
        for &(dy, dx) in Conn::Eight.offsets() {
            let ny = it.y as isize + dy;
            let nx = it.x as isize + dx;
            if ny < 0 || nx < 0 || ny >= h as isize || nx >= w as isize {
                continue;
            }
            let q = ny as usize * w + nx as usize;
            if mask.px[q] > 0.5 && labels[q] == 0.0 {
                labels[q] = it.label;
                heap.push(Item {
                    // flood never goes "below" the current level: classic
                    // priority-flood uses max(relief[q], current)
                    value: relief.px[q].max(it.value),
                    order,
                    y: ny as u32,
                    x: nx as u32,
                    label: it.label,
                });
                order += 1;
            }
        }
    }
    Gray { h, w, px: labels }
}

/// Regional maxima via the h-maxima criterion with h = 1:
/// maxima = (img - reconstruct(img - 1, img)) > 0.5, restricted to `mask`.
pub fn regional_maxima(img: &Gray, mask: &Gray) -> Gray {
    let marker = Gray {
        h: img.h,
        w: img.w,
        px: img.px.iter().map(|&v| v - 1.0).collect(),
    };
    let recon = reconstruct(&marker, img, Conn::Eight);
    let px = img
        .px
        .iter()
        .zip(&recon.px)
        .zip(&mask.px)
        .map(|((&g, &r), &m)| if g - r > 0.5 && m > 0.5 { 1.0 } else { 0.0 })
        .collect();
    Gray { h: img.h, w: img.w, px }
}

/// The Pre-Watershed stage: distance transform + labelled maxima markers.
/// Returns (relief = -distance, markers).  Matches `model.pre_watershed`.
pub fn pre_watershed(mask: &Gray) -> (Gray, Gray) {
    let dist = distance_chessboard(mask);
    let maxima = regional_maxima(&dist, mask);
    let (markers, _) = bwlabel(&maxima, Conn::Eight);
    let relief = Gray {
        h: dist.h,
        w: dist.w,
        px: dist.px.iter().map(|&v| -v).collect(),
    };
    (relief, markers)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_lobes(s: usize) -> Gray {
        // two overlapping disks -> single 8-connected component
        let mut m = Gray::zeros(s, s);
        let c = s as isize / 2;
        for y in 0..s {
            for x in 0..s {
                let dy = y as isize - c;
                let dx1 = x as isize - (c - 5);
                let dx2 = x as isize - (c + 5);
                if dy * dy + dx1 * dx1 <= 25 || dy * dy + dx2 * dx2 <= 25 {
                    m.set(y, x, 1.0);
                }
            }
        }
        m
    }

    #[test]
    fn splits_touching_nuclei() {
        let mask = two_lobes(24);
        let (relief, markers) = pre_watershed(&mask);
        let marker_ids: std::collections::BTreeSet<u32> = markers
            .px
            .iter()
            .filter(|&&v| v > 0.0)
            .map(|&v| v as u32)
            .collect();
        assert!(marker_ids.len() >= 2, "expected >= 2 markers, got {marker_ids:?}");
        let labels = watershed(&relief, &markers, &mask);
        // coverage: every mask pixel labelled, background untouched
        for i in 0..mask.px.len() {
            assert_eq!(labels.px[i] > 0.0, mask.px[i] > 0.5);
        }
        // the two lobe centres belong to different regions
        let c = 12;
        assert_ne!(labels.at(c, c - 5), labels.at(c, c + 5));
        // number of regions == number of markers
        let region_ids: std::collections::BTreeSet<u32> =
            labels.px.iter().filter(|&&v| v > 0.0).map(|&v| v as u32).collect();
        assert_eq!(region_ids, marker_ids);
    }

    #[test]
    fn markers_keep_their_pixels() {
        let mask = two_lobes(20);
        let (relief, markers) = pre_watershed(&mask);
        let labels = watershed(&relief, &markers, &mask);
        for i in 0..mask.px.len() {
            if markers.px[i] > 0.0 {
                assert_eq!(labels.px[i], markers.px[i], "marker pixel must keep its id");
            }
        }
    }

    #[test]
    fn isolated_blobs_one_region_each() {
        let mut mask = Gray::zeros(16, 16);
        for y in 2..6 {
            for x in 2..6 {
                mask.set(y, x, 1.0);
            }
        }
        for y in 10..14 {
            for x in 10..14 {
                mask.set(y, x, 1.0);
            }
        }
        let (relief, markers) = pre_watershed(&mask);
        let labels = watershed(&relief, &markers, &mask);
        assert_ne!(labels.at(3, 3), labels.at(12, 12));
        assert_eq!(labels.at(3, 3), labels.at(4, 4), "blob interior single region");
    }

    #[test]
    fn empty_mask_yields_empty_labels() {
        let mask = Gray::zeros(8, 8);
        let (relief, markers) = pre_watershed(&mask);
        let labels = watershed(&relief, &markers, &mask);
        assert!(labels.px.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn regional_maxima_finds_plateau_tops() {
        let mut img = Gray::filled(7, 7, 1.0);
        img.set(2, 2, 5.0);
        img.set(2, 3, 5.0); // plateau maximum of two pixels
        img.set(5, 5, 3.0); // second maximum
        let mask = Gray::filled(7, 7, 1.0);
        let mx = regional_maxima(&img, &mask);
        assert_eq!(mx.at(2, 2), 1.0);
        assert_eq!(mx.at(2, 3), 1.0);
        assert_eq!(mx.at(5, 5), 1.0);
        assert_eq!(mx.at(0, 0), 0.0);
    }
}
