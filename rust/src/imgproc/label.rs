//! Connected-component labelling (the paper's `BWLabel`).
//!
//! Two-pass union-find with compact 1..K relabelling — the classic CPU
//! algorithm.  The "GPU" variant (`model.bwlabel`) produces max-flat-index
//! labels instead; [`canonical_labels`] maps either convention to a
//! canonical form so tests can compare components across variants.

use super::{Conn, Gray};

/// Union-find with path halving.
struct Dsu {
    parent: Vec<u32>,
}

impl Dsu {
    fn new(n: usize) -> Self {
        Dsu { parent: (0..n as u32).collect() }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            self.parent[x as usize] = self.parent[self.parent[x as usize] as usize];
            x = self.parent[x as usize];
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra != rb {
            // attach larger id under smaller so roots are stable-ish
            if ra < rb {
                self.parent[rb as usize] = ra;
            } else {
                self.parent[ra as usize] = rb;
            }
        }
    }
}

/// Label the connected components of a binary (0/1) mask.
///
/// Returns a [`Gray`] whose pixels hold the component id (1..=K) as f32,
/// plus K itself.
pub fn bwlabel(mask: &Gray, conn: Conn) -> (Gray, usize) {
    let (h, w) = (mask.h, mask.w);
    let n = h * w;
    let mut dsu = Dsu::new(n);
    // pass 1: union with already-visited neighbours (raster order)
    let prior: &[(isize, isize)] = match conn {
        Conn::Four => &[(-1, 0), (0, -1)],
        Conn::Eight => &[(-1, -1), (-1, 0), (-1, 1), (0, -1)],
    };
    for y in 0..h {
        for x in 0..w {
            if mask.at(y, x) <= 0.5 {
                continue;
            }
            let p = (y * w + x) as u32;
            for &(dy, dx) in prior {
                let ny = y as isize + dy;
                let nx = x as isize + dx;
                if ny >= 0 && nx >= 0 && nx < w as isize && mask.at(ny as usize, nx as usize) > 0.5
                {
                    dsu.union(p, (ny as usize * w + nx as usize) as u32);
                }
            }
        }
    }
    // pass 2: compact roots to 1..K
    let mut next = 0u32;
    let mut compact = vec![0u32; n];
    let mut out = vec![0.0f32; n];
    for i in 0..n {
        if mask.px[i] <= 0.5 {
            continue;
        }
        let root = dsu.find(i as u32) as usize;
        if compact[root] == 0 {
            next += 1;
            compact[root] = next;
        }
        out[i] = compact[root] as f32;
    }
    (Gray { h, w, px: out }, next as usize)
}

/// Pixel areas per label; index 0 counts background.
pub fn label_areas(labels: &Gray, n_labels: usize) -> Vec<usize> {
    let mut areas = vec![0usize; n_labels + 1];
    for &v in &labels.px {
        let id = v as usize;
        if id <= n_labels {
            areas[id] += 1;
        }
    }
    areas
}

/// Canonicalise an arbitrary label image: components are renumbered 1..K in
/// raster order of their first pixel.  Two label images describe the same
/// segmentation iff their canonical forms are equal.
pub fn canonical_labels(labels: &Gray) -> Gray {
    let mut map: std::collections::HashMap<u64, f32> = std::collections::HashMap::new();
    let mut next = 0.0f32;
    let mut out = vec![0.0f32; labels.px.len()];
    for (i, &v) in labels.px.iter().enumerate() {
        if v <= 0.0 {
            continue;
        }
        let key = v.to_bits() as u64;
        let id = *map.entry(key).or_insert_with(|| {
            next += 1.0;
            next
        });
        out[i] = id;
    }
    Gray { h: labels.h, w: labels.w, px: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn two_blocks_two_labels() {
        let mut m = Gray::zeros(10, 10);
        for y in 1..4 {
            for x in 1..4 {
                m.set(y, x, 1.0);
            }
        }
        for y in 6..9 {
            for x in 6..9 {
                m.set(y, x, 1.0);
            }
        }
        let (lab, k) = bwlabel(&m, Conn::Eight);
        assert_eq!(k, 2);
        assert_ne!(lab.at(2, 2), lab.at(7, 7));
        assert_eq!(lab.at(0, 0), 0.0);
        let areas = label_areas(&lab, k);
        assert_eq!(areas[1], 9);
        assert_eq!(areas[2], 9);
    }

    #[test]
    fn diagonal_conn_matters() {
        let mut m = Gray::zeros(4, 4);
        m.set(0, 0, 1.0);
        m.set(1, 1, 1.0);
        m.set(2, 2, 1.0);
        let (_, k8) = bwlabel(&m, Conn::Eight);
        assert_eq!(k8, 1);
        let (_, k4) = bwlabel(&m, Conn::Four);
        assert_eq!(k4, 3);
    }

    #[test]
    fn labels_partition_foreground() {
        forall(
            "bwlabel partitions fg",
            25,
            |r: &mut Rng| {
                let h = r.range(2, 16);
                let w = r.range(2, 16);
                (h, w, r.mask(h, w, 0.4))
            },
            |(h, w, px)| {
                let m = Gray::new(*h, *w, px.clone()).unwrap();
                let (lab, k) = bwlabel(&m, Conn::Eight);
                for i in 0..px.len() {
                    let fg = px[i] > 0.5;
                    if fg != (lab.px[i] > 0.0) {
                        return Err(format!("support mismatch at {i}"));
                    }
                    if lab.px[i] > k as f32 {
                        return Err(format!("label out of range at {i}"));
                    }
                }
                // areas sum to foreground count
                let areas = label_areas(&lab, k);
                let fg: usize = px.iter().filter(|&&v| v > 0.5).count();
                if areas[1..].iter().sum::<usize>() != fg {
                    return Err("areas don't sum".into());
                }
                // each label 1..k non-empty
                if areas[1..].iter().any(|&a| a == 0) {
                    return Err("empty label id".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn neighbours_share_labels() {
        forall(
            "adjacent fg pixels share label",
            20,
            |r: &mut Rng| {
                let h = r.range(3, 12);
                let w = r.range(3, 12);
                (h, w, r.mask(h, w, 0.6))
            },
            |(h, w, px)| {
                let m = Gray::new(*h, *w, px.clone()).unwrap();
                let (lab, _) = bwlabel(&m, Conn::Eight);
                for y in 0..*h {
                    for x in 0..*w {
                        if m.at(y, x) <= 0.5 {
                            continue;
                        }
                        for &(dy, dx) in Conn::Eight.offsets() {
                            let ny = y as isize + dy;
                            let nx = x as isize + dx;
                            if ny >= 0
                                && nx >= 0
                                && ny < *h as isize
                                && nx < *w as isize
                                && m.at(ny as usize, nx as usize) > 0.5
                                && lab.at(y, x) != lab.at(ny as usize, nx as usize)
                            {
                                return Err(format!("split component at ({y},{x})"));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn canonical_labels_identifies_equivalent_labelings() {
        let a = Gray::new(1, 6, vec![5.0, 5.0, 0.0, 9.0, 9.0, 5.0]).unwrap();
        let b = Gray::new(1, 6, vec![2.0, 2.0, 0.0, 1.0, 1.0, 2.0]).unwrap();
        assert_eq!(canonical_labels(&a).px, canonical_labels(&b).px);
        let c = Gray::new(1, 6, vec![2.0, 2.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        assert_ne!(canonical_labels(&a).px, canonical_labels(&c).px);
    }
}
