//! Image-processing substrate: the **CPU function variants** of every
//! operation in the paper's Fig. 1 pipeline (Table I's "CPU source" column).
//!
//! The paper used OpenCV + Vincent's morphological reconstruction + its own
//! implementations; this module provides the equivalents from scratch:
//!
//! | paper op           | here |
//! |--------------------|------|
//! | RBC detection      | [`color`] deconvolution + [`morphology`] open |
//! | Morph. Open        | [`morphology`] |
//! | ReconToNuclei      | [`reconstruct`] (Vincent hybrid raster+queue) |
//! | AreaThreshold      | [`threshold`] (+ [`label`]) |
//! | FillHolles         | [`morphology`] fill_holes |
//! | Pre-Watershed      | [`distance`] + regional maxima |
//! | Watershed          | [`watershed`] (priority-flood) |
//! | BWLabel            | [`label`] (two-pass union-find) |
//! | Features comp.     | [`stats`], [`convolve`], [`canny`], [`haralick`], [`objfeatures`] |
//!
//! Semantics deliberately match the JAX graphs in `python/compile/model.py`
//! (the "GPU" variants) so integration tests can compare the two sides of
//! each function variant; the documented exceptions are `bwlabel` (compact
//! vs max-index labels — same components) and `watershed` (priority-flood vs
//! synchronous flood — both valid tessellations, like the paper's
//! OpenCV-vs-Körbes pair).

pub mod canny;
pub mod color;
pub mod convolve;
pub mod distance;
pub mod haralick;
pub mod label;
pub mod morphology;
pub mod objfeatures;
pub mod reconstruct;
pub mod stats;
pub mod threshold;
pub mod watershed;

use crate::runtime::HostTensor;
use crate::{Error, Result};

/// A single-channel f32 image (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Gray {
    pub h: usize,
    pub w: usize,
    pub px: Vec<f32>,
}

impl Gray {
    pub fn new(h: usize, w: usize, px: Vec<f32>) -> Result<Self> {
        if px.len() != h * w {
            return Err(Error::ImgProc(format!(
                "gray image {h}x{w} needs {} px, got {}",
                h * w,
                px.len()
            )));
        }
        Ok(Self { h, w, px })
    }

    pub fn zeros(h: usize, w: usize) -> Self {
        Self { h, w, px: vec![0.0; h * w] }
    }

    pub fn filled(h: usize, w: usize, v: f32) -> Self {
        Self { h, w, px: vec![v; h * w] }
    }

    #[inline(always)]
    pub fn at(&self, y: usize, x: usize) -> f32 {
        self.px[y * self.w + x]
    }

    #[inline(always)]
    pub fn set(&mut self, y: usize, x: usize, v: f32) {
        self.px[y * self.w + x] = v;
    }

    /// Replicate-clamped read (edge padding semantics).
    #[inline(always)]
    pub fn at_clamped(&self, y: isize, x: isize) -> f32 {
        let y = y.clamp(0, self.h as isize - 1) as usize;
        let x = x.clamp(0, self.w as isize - 1) as usize;
        self.at(y, x)
    }

    pub fn to_tensor(&self) -> HostTensor {
        HostTensor::new(vec![self.h, self.w], self.px.clone()).expect("shape consistent")
    }

    pub fn from_tensor(t: &HostTensor) -> Result<Self> {
        if t.shape().len() != 2 {
            return Err(Error::ImgProc(format!(
                "expected rank-2 tensor, got {:?}",
                t.shape()
            )));
        }
        Gray::new(t.shape()[0], t.shape()[1], t.data().to_vec())
    }

    /// Count of pixels strictly greater than `thresh`.
    pub fn count_above(&self, thresh: f32) -> usize {
        self.px.iter().filter(|&&v| v > thresh).count()
    }

    pub fn max_abs_diff(&self, other: &Gray) -> f32 {
        self.px
            .iter()
            .zip(&other.px)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// An interleaved RGB f32 image (row-major, 3 channels).
#[derive(Debug, Clone, PartialEq)]
pub struct Rgb {
    pub h: usize,
    pub w: usize,
    pub px: Vec<f32>,
}

impl Rgb {
    pub fn new(h: usize, w: usize, px: Vec<f32>) -> Result<Self> {
        if px.len() != h * w * 3 {
            return Err(Error::ImgProc(format!(
                "rgb image {h}x{w} needs {} px, got {}",
                h * w * 3,
                px.len()
            )));
        }
        Ok(Self { h, w, px })
    }

    pub fn filled(h: usize, w: usize, rgb: [f32; 3]) -> Self {
        let mut px = Vec::with_capacity(h * w * 3);
        for _ in 0..h * w {
            px.extend_from_slice(&rgb);
        }
        Self { h, w, px }
    }

    #[inline(always)]
    pub fn at(&self, y: usize, x: usize, c: usize) -> f32 {
        self.px[(y * self.w + x) * 3 + c]
    }

    #[inline(always)]
    pub fn set(&mut self, y: usize, x: usize, rgb: [f32; 3]) {
        let i = (y * self.w + x) * 3;
        self.px[i..i + 3].copy_from_slice(&rgb);
    }

    pub fn to_tensor(&self) -> HostTensor {
        HostTensor::new(vec![self.h, self.w, 3], self.px.clone()).expect("shape consistent")
    }

    pub fn from_tensor(t: &HostTensor) -> Result<Self> {
        if t.shape().len() != 3 || t.shape()[2] != 3 {
            return Err(Error::ImgProc(format!(
                "expected HxWx3 tensor, got {:?}",
                t.shape()
            )));
        }
        Rgb::new(t.shape()[0], t.shape()[1], t.data().to_vec())
    }
}

/// Connectivity of neighbourhood operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Conn {
    Four,
    Eight,
}

impl Conn {
    /// Neighbour offsets excluding the centre.
    pub fn offsets(self) -> &'static [(isize, isize)] {
        match self {
            Conn::Four => &[(-1, 0), (1, 0), (0, -1), (0, 1)],
            Conn::Eight => &[
                (-1, -1),
                (-1, 0),
                (-1, 1),
                (0, -1),
                (0, 1),
                (1, -1),
                (1, 0),
                (1, 1),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gray_shape_checked() {
        assert!(Gray::new(2, 3, vec![0.0; 6]).is_ok());
        assert!(Gray::new(2, 3, vec![0.0; 7]).is_err());
    }

    #[test]
    fn clamped_reads() {
        let g = Gray::new(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(g.at_clamped(-5, -5), 1.0);
        assert_eq!(g.at_clamped(5, 5), 4.0);
        assert_eq!(g.at_clamped(0, 1), 2.0);
    }

    #[test]
    fn tensor_roundtrip() {
        let g = Gray::new(2, 3, (0..6).map(|v| v as f32).collect()).unwrap();
        let back = Gray::from_tensor(&g.to_tensor()).unwrap();
        assert_eq!(g, back);
        let rgb = Rgb::filled(2, 2, [1.0, 2.0, 3.0]);
        let back = Rgb::from_tensor(&rgb.to_tensor()).unwrap();
        assert_eq!(rgb, back);
    }

    #[test]
    fn conn_offsets() {
        assert_eq!(Conn::Four.offsets().len(), 4);
        assert_eq!(Conn::Eight.offsets().len(), 8);
    }
}
