//! Per-tile pixel statistics — CPU variant of
//! `python/compile/kernels/stats.py` (identical layout: sum, sumsq, min,
//! max, 16-bin histogram over [0, 256)).

use super::Gray;

pub const STATS_LEN: usize = 20;
pub const HIST_BINS: usize = 16;
pub const HIST_RANGE: f32 = 256.0;

/// f32[20] statistics vector: [sum, sumsq, min, max, hist16...].
pub fn tile_stats(img: &Gray) -> [f32; STATS_LEN] {
    let mut sum = 0.0f64;
    let mut sumsq = 0.0f64;
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    let mut hist = [0.0f32; HIST_BINS];
    let width = HIST_RANGE / HIST_BINS as f32;
    for &v in &img.px {
        sum += v as f64;
        sumsq += (v as f64) * (v as f64);
        min = min.min(v);
        max = max.max(v);
        let clipped = v.clamp(0.0, HIST_RANGE - 1e-3);
        hist[(clipped / width) as usize] += 1.0;
    }
    let mut out = [0.0f32; STATS_LEN];
    out[0] = sum as f32;
    out[1] = sumsq as f32;
    out[2] = min;
    out[3] = max;
    out[4..].copy_from_slice(&hist);
    out
}

/// Mean and (population) standard deviation from a stats vector.
pub fn mean_std(stats: &[f32; STATS_LEN], n_pixels: usize) -> (f32, f32) {
    let n = n_pixels as f64;
    let mean = stats[0] as f64 / n;
    let var = (stats[1] as f64 / n - mean * mean).max(0.0);
    (mean as f32, var.sqrt() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn constant_image_stats() {
        let img = Gray::filled(8, 8, 100.0);
        let s = tile_stats(&img);
        assert_eq!(s[0], 6400.0);
        assert_eq!(s[2], 100.0);
        assert_eq!(s[3], 100.0);
        assert_eq!(s[4 + 6], 64.0); // 100/16 = 6.25 -> bin 6
        let (mean, std) = mean_std(&s, 64);
        assert_eq!(mean, 100.0);
        assert!(std.abs() < 1e-3);
    }

    #[test]
    fn histogram_mass_equals_pixels() {
        forall(
            "hist sums to n",
            20,
            |r: &mut Rng| {
                let h = r.range(1, 20);
                let w = r.range(1, 20);
                (h, w, r.image(h, w))
            },
            |(h, w, px)| {
                let img = Gray::new(*h, *w, px.clone()).unwrap();
                let s = tile_stats(&img);
                let mass: f32 = s[4..].iter().sum();
                if mass != (h * w) as f32 {
                    return Err(format!("mass {mass} != {}", h * w));
                }
                if s[2] > s[3] {
                    return Err("min > max".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn out_of_range_values_clamp_into_edge_bins() {
        let img = Gray::new(1, 3, vec![-50.0, 300.0, 255.9]).unwrap();
        let s = tile_stats(&img);
        assert_eq!(s[4], 1.0); // -50 clamps to bin 0
        assert_eq!(s[4 + 15], 2.0); // 300 and 255.9 clamp to last bin
    }
}
