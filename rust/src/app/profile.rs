//! Calibrated per-operation cost profile (the paper's Fig. 7 data).
//!
//! For every fine-grain operation: the fraction of single-core CPU time it
//! accounts for, the GPU-vs-CPU speedup (computation only), and the
//! transfer impact (fraction of GPU execution spent moving data; the paper
//! reports data transfers cost ~13% overall).  The exact Fig. 7 bar values
//! are only published as a bitmap; these numbers preserve the properties
//! the runtime depends on and that the paper states in prose:
//!
//! * feature computation accelerates best (regular, compute-bound);
//! * Morph. Open accelerates worst (4% of CPU time but 23% of the GPU
//!   pipeline's time);
//! * the reconstruction-based ops (ReconToNuclei, FillHolles,
//!   Pre-Watershed) land in the middle-high range thanks to the authors'
//!   queue-based MR kernel;
//! * irregular label/area ops accelerate modestly.
//!
//! The same table calibrates PATS estimates, the simulator's device model,
//! and the Fig. 13 error-injection experiments.

/// One operation's profile entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpProfileEntry {
    pub name: &'static str,
    /// Fraction of single-core CPU time for one tile (sums to 1.0).
    pub cpu_fraction: f64,
    /// GPU-vs-1-core speedup, computation only (Fig. 7 dark bars).
    pub speedup: f32,
    /// Fraction of GPU op time spent in CPU<->GPU transfer (drives the DL
    /// decision rule and the "computation + data transfer" Fig. 7 bars).
    pub transfer_impact: f32,
}

impl OpProfileEntry {
    /// Speedup including transfer overhead (Fig. 7 light bars).
    pub fn speedup_with_transfer(&self) -> f32 {
        self.speedup * (1.0 - self.transfer_impact)
    }
}

/// The segmentation + feature-computation profile (paper Table I ops).
pub const PROFILE: &[OpProfileEntry] = &[
    OpProfileEntry { name: "hema_prep", cpu_fraction: 0.02, speedup: 1.0, transfer_impact: 0.0 },
    OpProfileEntry { name: "rbc_detect", cpu_fraction: 0.08, speedup: 3.0, transfer_impact: 0.22 },
    OpProfileEntry { name: "morph_open", cpu_fraction: 0.04, speedup: 1.6, transfer_impact: 0.35 },
    OpProfileEntry {
        name: "recon_to_nuclei",
        cpu_fraction: 0.18,
        speedup: 9.0,
        transfer_impact: 0.08,
    },
    OpProfileEntry {
        name: "area_threshold",
        cpu_fraction: 0.03,
        speedup: 1.8,
        transfer_impact: 0.35,
    },
    OpProfileEntry { name: "fill_holes", cpu_fraction: 0.10, speedup: 7.5, transfer_impact: 0.10 },
    OpProfileEntry {
        name: "pre_watershed",
        cpu_fraction: 0.12,
        speedup: 10.0,
        transfer_impact: 0.10,
    },
    OpProfileEntry { name: "watershed", cpu_fraction: 0.12, speedup: 7.0, transfer_impact: 0.15 },
    OpProfileEntry { name: "bwlabel", cpu_fraction: 0.04, speedup: 2.0, transfer_impact: 0.30 },
    OpProfileEntry {
        name: "feature_graph",
        cpu_fraction: 0.20,
        speedup: 16.0,
        transfer_impact: 0.12,
    },
    OpProfileEntry {
        name: "object_features",
        cpu_fraction: 0.05,
        speedup: 1.0,
        transfer_impact: 0.0,
    },
    OpProfileEntry { name: "haralick", cpu_fraction: 0.02, speedup: 1.0, transfer_impact: 0.0 },
];

/// Look up an op's profile entry.
pub fn entry(name: &str) -> Option<&'static OpProfileEntry> {
    PROFILE.iter().find(|e| e.name == name)
}

/// Speedup estimate for PATS (1.0 when unknown).
pub fn speedup_of(name: &str) -> f32 {
    entry(name).map(|e| e.speedup).unwrap_or(1.0)
}

/// Transfer impact for the DL rule (0.0 when unknown).
pub fn transfer_impact_of(name: &str) -> f32 {
    entry(name).map(|e| e.transfer_impact).unwrap_or(0.0)
}

/// The static Fig. 7 table expressed as a [`ProfileStore`], as if an
/// offline calibration pass had measured exactly the paper's numbers: per
/// op, CPU time = `cpu_fraction` of a 1000 ms tile and GPU time =
/// CPU/speedup, so `store.speedup(op)` reproduces the table.  Useful as a
/// baseline to diff measured stores against, and in tests that need a
/// fully-populated store without running a calibration pass.
pub fn fig7_store() -> crate::runtime::calibrate::ProfileStore {
    use crate::metrics::DeviceKind;
    use std::time::Duration;
    const TILE_MS: f64 = 1000.0;
    let mut store = crate::runtime::calibrate::ProfileStore::new(64);
    for e in PROFILE {
        let cpu_ms = e.cpu_fraction * TILE_MS;
        store.record(e.name, DeviceKind::Cpu, Duration::from_secs_f64(cpu_ms / 1e3));
        store.record(
            e.name,
            DeviceKind::Gpu,
            Duration::from_secs_f64(cpu_ms / e.speedup as f64 / 1e3),
        );
        store.record_transfer_impact(e.name, e.transfer_impact);
    }
    store
}

/// Time-weighted blended speedup over a set of ops — the effective speedup
/// of a *monolithic* stage (Amdahl over the op mix).
pub fn blended_speedup(names: &[&str]) -> f32 {
    let mut cpu_total = 0.0f64;
    let mut gpu_total = 0.0f64;
    for n in names {
        if let Some(e) = entry(n) {
            cpu_total += e.cpu_fraction;
            gpu_total += e.cpu_fraction / e.speedup as f64;
        }
    }
    if gpu_total <= 0.0 {
        1.0
    } else {
        (cpu_total / gpu_total) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let sum: f64 = PROFILE.iter().map(|e| e.cpu_fraction).sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum = {sum}");
    }

    #[test]
    fn ordering_matches_paper_narrative() {
        // features best, morph open worst among GPU-capable ops
        let best = PROFILE.iter().filter(|e| e.speedup > 1.0).map(|e| e.speedup).fold(0.0, f32::max);
        assert_eq!(best, speedup_of("feature_graph"));
        let worst = PROFILE
            .iter()
            .filter(|e| e.speedup > 1.0)
            .map(|e| e.speedup)
            .fold(f32::INFINITY, f32::min);
        assert_eq!(worst, speedup_of("morph_open"));
    }

    #[test]
    fn blended_speedup_is_amdahl_bounded() {
        let all: Vec<&str> = PROFILE.iter().map(|e| e.name).collect();
        let blended = blended_speedup(&all);
        // bounded by min and max member speedups
        assert!(blended > 1.0 && blended < 15.0, "blended = {blended}");
        // the segmentation-only blend is lower than features-only
        let seg = blended_speedup(&["recon_to_nuclei", "morph_open", "watershed"]);
        let feat = blended_speedup(&["feature_graph"]);
        assert!(seg < feat);
    }

    #[test]
    fn transfer_reduces_effective_speedup() {
        let e = entry("feature_graph").unwrap();
        assert!(e.speedup_with_transfer() < e.speedup);
        assert!((e.speedup_with_transfer() - 16.0 * 0.88).abs() < 1e-4);
    }

    #[test]
    fn unknown_ops_default_neutral() {
        assert_eq!(speedup_of("nope"), 1.0);
        assert_eq!(transfer_impact_of("nope"), 0.0);
    }

    #[test]
    fn fig7_store_reproduces_the_static_table() {
        let store = fig7_store();
        assert_eq!(store.len(), PROFILE.len());
        for e in PROFILE {
            let s = store.speedup(e.name).unwrap();
            assert!((s - e.speedup).abs() < 1e-3, "{}: {s} vs {}", e.name, e.speedup);
            let est = store.estimate(e.name).unwrap();
            assert_eq!(est.transfer_impact, Some(e.transfer_impact), "{}", e.name);
        }
    }
}
