//! CPU function-variant closures for the WSI pipeline operations.
//!
//! Each function here matches the semantics of the same-named JAX graph in
//! `python/compile/model.py` (the accelerator member of the variant); the
//! documented exceptions are label numbering (bwlabel) and the watershed
//! algorithm (priority-flood vs synchronous flood) — the same CPU/GPU
//! algorithmic divergence the paper had with OpenCV vs Körbes.

use crate::imgproc::{
    canny, color, convolve, distance, haralick, label, morphology, objfeatures, reconstruct,
    stats, threshold, watershed, Conn, Gray, Rgb,
};
use crate::runtime::{HostTensor, Value};
use crate::{Error, Result};

fn gray_arg(args: &[Value], i: usize) -> Result<Gray> {
    Gray::from_tensor(args.get(i).ok_or_else(|| miss(i))?.as_tensor()?)
}

fn rgb_arg(args: &[Value], i: usize) -> Result<Rgb> {
    Rgb::from_tensor(args.get(i).ok_or_else(|| miss(i))?.as_tensor()?)
}

fn scalar_arg(args: &[Value], i: usize) -> Result<f32> {
    args.get(i).ok_or_else(|| miss(i))?.as_scalar()
}

fn miss(i: usize) -> Error {
    Error::Dataflow(format!("missing argument {i}"))
}

fn out(g: Gray) -> Value {
    Value::Tensor(g.to_tensor())
}

/// hema_prep: rgb -> hematoxylin channel scaled to [0, 256).
pub fn hema_prep(args: &[Value]) -> Result<Vec<Value>> {
    let rgb = rgb_arg(args, 0)?;
    Ok(vec![out(color::hema_image(&rgb)?)])
}

/// rbc_detect: rgb, ratio -> binary RBC mask (eosin-dominant, opened).
pub fn rbc_detect(args: &[Value]) -> Result<Vec<Value>> {
    let rgb = rgb_arg(args, 0)?;
    let ratio = scalar_arg(args, 1)?;
    let stains = color::color_deconv(&rgb)?;
    let mut raw = Gray::zeros(rgb.h, rgb.w);
    for i in 0..raw.px.len() {
        if stains.eosin.px[i] > ratio * stains.hematoxylin.px[i] {
            raw.px[i] = 1.0;
        }
    }
    let opened = morphology::dilate3x3(&morphology::erode3x3(&raw, Conn::Eight), Conn::Eight);
    Ok(vec![out(opened)])
}

/// morph_open: gray -> opening by the radius-2 diamond.
pub fn morph_open(args: &[Value]) -> Result<Vec<Value>> {
    let g = gray_arg(args, 0)?;
    Ok(vec![out(morphology::morph_open(&g))])
}

/// recon_to_nuclei: gray, h, thresh -> candidate nuclei mask (h-dome).
pub fn recon_to_nuclei(args: &[Value]) -> Result<Vec<Value>> {
    let g = gray_arg(args, 0)?;
    let h = scalar_arg(args, 1)?;
    let t = scalar_arg(args, 2)?;
    let dome = reconstruct::hdome(&g, h, Conn::Eight);
    Ok(vec![out(threshold::threshold(&dome, t))])
}

/// fill_holes: mask -> mask with interior holes filled.
pub fn fill_holes(args: &[Value]) -> Result<Vec<Value>> {
    let m = gray_arg(args, 0)?;
    Ok(vec![out(morphology::fill_holes(&m))])
}

/// area_threshold: mask, lo, hi -> components within the area band.
pub fn area_threshold(args: &[Value]) -> Result<Vec<Value>> {
    let m = gray_arg(args, 0)?;
    let lo = scalar_arg(args, 1)?;
    let hi = scalar_arg(args, 2)?;
    Ok(vec![out(threshold::area_threshold(&m, lo, hi))])
}

/// bwlabel: mask -> component labels (compact 1..K numbering).
pub fn bwlabel(args: &[Value]) -> Result<Vec<Value>> {
    let m = gray_arg(args, 0)?;
    let (labels, _) = label::bwlabel(&m, Conn::Eight);
    Ok(vec![out(labels)])
}

/// pre_watershed: mask -> (relief = -distance, marker labels).
pub fn pre_watershed(args: &[Value]) -> Result<Vec<Value>> {
    let m = gray_arg(args, 0)?;
    let (relief, markers) = watershed::pre_watershed(&m);
    Ok(vec![out(relief), out(markers)])
}

/// watershed: relief, markers, mask -> nucleus labels.
pub fn watershed_op(args: &[Value]) -> Result<Vec<Value>> {
    let relief = gray_arg(args, 0)?;
    let markers = gray_arg(args, 1)?;
    let mask = gray_arg(args, 2)?;
    Ok(vec![out(watershed::watershed(&relief, &markers, &mask))])
}

/// distance: mask -> chessboard distance map.
pub fn distance_op(args: &[Value]) -> Result<Vec<Value>> {
    let m = gray_arg(args, 0)?;
    Ok(vec![out(distance::distance_chessboard(&m))])
}

/// morph_recon: marker, mask -> grayscale reconstruction.
pub fn morph_recon(args: &[Value]) -> Result<Vec<Value>> {
    let marker = gray_arg(args, 0)?;
    let mask = gray_arg(args, 1)?;
    Ok(vec![out(reconstruct::reconstruct(&marker, &mask, Conn::Eight))])
}

/// feature_graph: rgb, edge_t -> (hema, gradient magnitude, edges, stats41).
/// Matches `model.feature_graph` exactly (simple threshold edges).
pub fn feature_graph(args: &[Value]) -> Result<Vec<Value>> {
    let rgb = rgb_arg(args, 0)?;
    let edge_t = scalar_arg(args, 1)?;
    let hema = color::hema_image(&rgb)?;
    let smooth = convolve::gaussian3(&hema);
    let gmag = convolve::sobel_magnitude(&smooth);
    let edges = threshold::threshold(&gmag, edge_t);
    let s_h = stats::tile_stats(&hema);
    let s_g = stats::tile_stats(&gmag);
    let edge_count: f32 = edges.px.iter().sum();
    let mut v = Vec::with_capacity(41);
    v.extend_from_slice(&s_h);
    v.extend_from_slice(&s_g);
    v.push(edge_count);
    Ok(vec![
        out(hema),
        out(gmag),
        out(edges),
        Value::Tensor(HostTensor::new(vec![41], v)?),
    ])
}

/// object_features: labels, hema, gmag, edges -> flat [n, 12] matrix of
/// per-nucleus morphometry + intensity features (CPU-only; irregular).
pub fn object_features(args: &[Value]) -> Result<Vec<Value>> {
    let labels = gray_arg(args, 0)?;
    let hema = gray_arg(args, 1)?;
    let gmag = gray_arg(args, 2)?;
    let edges = gray_arg(args, 3)?;
    let n_labels = labels.px.iter().fold(0.0f32, |a, &b| a.max(b)) as usize;
    let feats = objfeatures::object_features(&labels, n_labels, &hema, &gmag, &edges);
    let n = feats.len();
    let mut flat = Vec::with_capacity(n * 12);
    for f in &feats {
        flat.extend_from_slice(&f.to_vec());
    }
    Ok(vec![Value::Tensor(HostTensor::new(vec![n, 12], flat)?)])
}

/// haralick: hema, labels -> 5 mean Haralick texture features over tissue.
pub fn haralick_op(args: &[Value]) -> Result<Vec<Value>> {
    let hema = gray_arg(args, 0)?;
    let labels = gray_arg(args, 1)?;
    let mask = Gray {
        h: labels.h,
        w: labels.w,
        px: labels.px.iter().map(|&v| if v > 0.0 { 1.0 } else { 0.0 }).collect(),
    };
    let f = haralick::haralick(&hema, &mask);
    Ok(vec![Value::Tensor(HostTensor::new(vec![5], f.to_vec().to_vec())?)])
}

/// canny edges (extension op; richer than the threshold edge mask).
pub fn canny_op(args: &[Value]) -> Result<Vec<Value>> {
    let g = gray_arg(args, 0)?;
    let lo = scalar_arg(args, 1)?;
    let hi = scalar_arg(args, 2)?;
    Ok(vec![out(canny::canny(&g, lo, hi))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthConfig, TileSynthesizer};

    fn tile() -> Value {
        let synth = TileSynthesizer::new(SynthConfig::small());
        Value::Tensor(synth.tissue_tile(0).to_tensor())
    }

    #[test]
    fn segmentation_chain_finds_nuclei() {
        let rgb = tile();
        let hema = hema_prep(&[rgb.clone()]).unwrap();
        let opened = morph_open(&hema).unwrap();
        let cand = recon_to_nuclei(&[opened[0].clone(), Value::Scalar(20.0), Value::Scalar(5.0)])
            .unwrap();
        let filled = fill_holes(&cand).unwrap();
        let kept =
            area_threshold(&[filled[0].clone(), Value::Scalar(5.0), Value::Scalar(500.0)]).unwrap();
        let pw = pre_watershed(&kept).unwrap();
        let labels =
            watershed_op(&[pw[0].clone(), pw[1].clone(), kept[0].clone()]).unwrap();
        let lab = Gray::from_tensor(labels[0].as_tensor().unwrap()).unwrap();
        let n = lab.px.iter().fold(0.0f32, |a, &b| a.max(b)) as usize;
        assert!(n >= 1, "expected at least one nucleus, got {n}");
    }

    #[test]
    fn rbc_mask_is_binary() {
        let m = rbc_detect(&[tile(), Value::Scalar(1.2)]).unwrap();
        let g = Gray::from_tensor(m[0].as_tensor().unwrap()).unwrap();
        assert!(g.px.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn feature_graph_consistency() {
        let outs = feature_graph(&[tile(), Value::Scalar(30.0)]).unwrap();
        assert_eq!(outs.len(), 4);
        let stats = outs[3].as_tensor().unwrap();
        assert_eq!(stats.shape(), &[41]);
        let edges = outs[2].as_tensor().unwrap();
        let edge_sum: f32 = edges.data().iter().sum();
        assert_eq!(stats.data()[40], edge_sum);
    }

    #[test]
    fn object_features_shape() {
        let rgb = tile();
        let hema = hema_prep(&[rgb.clone()]).unwrap();
        let cand = recon_to_nuclei(&[hema[0].clone(), Value::Scalar(20.0), Value::Scalar(5.0)])
            .unwrap();
        let labels = bwlabel(&cand).unwrap();
        let fg = feature_graph(&[rgb, Value::Scalar(30.0)]).unwrap();
        let of = object_features(&[
            labels[0].clone(),
            fg[0].clone(),
            fg[1].clone(),
            fg[2].clone(),
        ])
        .unwrap();
        let t = of[0].as_tensor().unwrap();
        assert_eq!(t.shape().len(), 2);
        assert_eq!(t.shape()[1], 12);
    }

    #[test]
    fn wrong_arity_is_error() {
        assert!(hema_prep(&[]).is_err());
        assert!(recon_to_nuclei(&[tile()]).is_err());
    }
}
