//! A generic, non-WSI demo workload: convolve → threshold → label → stats.
//!
//! The point of this module is to prove the [`OpRegistry`] +
//! [`WorkflowBuilder`](crate::dataflow::WorkflowBuilder) + JSON-loader API
//! is workload-agnostic: none of these operations know anything about H&E
//! staining or the paper's pipeline, yet the same Manager/WRM machinery
//! executes them end-to-end (see `examples/generic_pipeline.rs` and the
//! `workflow_builder` integration tests).
//!
//! The workload ("cell-stats") counts bright blobs per image chunk:
//!
//! * stage `detect` (per-chunk): grayscale → invert → Gaussian smooth →
//!   binarize → connected components → per-chunk region statistics;
//! * stage `aggregate` (reduce): element-wise mean of every chunk's
//!   statistics vector.
//!
//! The whole workflow is described as data ([`CELL_STATS_JSON`]) and loaded
//! against [`generic_registry`].

use crate::dataflow::{workflow_from_str, OpRegistry, OpSpec, Workflow};
use crate::imgproc::{convolve, label, threshold, Conn, Gray, Rgb};
use crate::runtime::{HostTensor, Value};
use crate::{Error, Result};
use std::sync::Arc;

fn gray_arg(args: &[Value], i: usize) -> Result<Gray> {
    Gray::from_tensor(
        args.get(i)
            .ok_or_else(|| Error::Dataflow(format!("missing argument {i}")))?
            .as_tensor()?,
    )
}

fn out(g: Gray) -> Value {
    Value::Tensor(g.to_tensor())
}

/// rgb -> gray: per-pixel channel mean.
pub fn grayscale(args: &[Value]) -> Result<Vec<Value>> {
    let rgb = Rgb::from_tensor(
        args.first()
            .ok_or_else(|| Error::Dataflow("missing argument 0".into()))?
            .as_tensor()?,
    )?;
    let mut g = Gray::zeros(rgb.h, rgb.w);
    for y in 0..rgb.h {
        for x in 0..rgb.w {
            let v = (rgb.at(y, x, 0) + rgb.at(y, x, 1) + rgb.at(y, x, 2)) / 3.0;
            g.set(y, x, v);
        }
    }
    Ok(vec![out(g)])
}

/// gray -> 255 - gray (dark blobs become bright).
pub fn invert(args: &[Value]) -> Result<Vec<Value>> {
    let g = gray_arg(args, 0)?;
    let px = g.px.iter().map(|&v| 255.0 - v).collect();
    Ok(vec![out(Gray::new(g.h, g.w, px)?)])
}

/// gray -> 3x3 Gaussian smooth.
pub fn gauss3(args: &[Value]) -> Result<Vec<Value>> {
    let g = gray_arg(args, 0)?;
    Ok(vec![out(convolve::gaussian3(&g))])
}

/// gray -> Sobel gradient magnitude.
pub fn sobel(args: &[Value]) -> Result<Vec<Value>> {
    let g = gray_arg(args, 0)?;
    Ok(vec![out(convolve::sobel_magnitude(&g))])
}

/// gray, t -> binary mask (1.0 where gray > t).
pub fn binarize(args: &[Value]) -> Result<Vec<Value>> {
    let g = gray_arg(args, 0)?;
    let t = args
        .get(1)
        .ok_or_else(|| Error::Dataflow("missing argument 1".into()))?
        .as_scalar()?;
    Ok(vec![out(threshold::threshold(&g, t))])
}

/// mask -> 8-connected component labels (compact 1..K numbering).
pub fn cc_label(args: &[Value]) -> Result<Vec<Value>> {
    let m = gray_arg(args, 0)?;
    let (labels, _) = label::bwlabel(&m, Conn::Eight);
    Ok(vec![out(labels)])
}

/// labels -> [n_regions, mean_area, max_area, coverage] (length-4 vector).
pub fn region_stats(args: &[Value]) -> Result<Vec<Value>> {
    let labels = gray_arg(args, 0)?;
    let n = labels.px.iter().fold(0.0f32, |a, &b| a.max(b)) as usize;
    let (mean_area, max_area) = if n == 0 {
        (0.0, 0.0)
    } else {
        let areas = label::label_areas(&labels, n);
        let fg: usize = areas.iter().skip(1).sum();
        let max = areas.iter().skip(1).copied().max().unwrap_or(0);
        (fg as f32 / n as f32, max as f32)
    };
    let coverage = labels.px.iter().filter(|&&v| v > 0.0).count() as f32
        / labels.px.len().max(1) as f32;
    Ok(vec![Value::Tensor(HostTensor::new(
        vec![4],
        vec![n as f32, mean_area, max_area, coverage],
    )?)])
}

/// Reduce member: element-wise mean over every chunk's stats vector.
pub fn mean_stats(args: &[Value]) -> Result<Vec<Value>> {
    if args.is_empty() {
        return Err(Error::Dataflow("mean_stats needs at least one input".into()));
    }
    let first = args[0].as_tensor()?;
    let len = first.len();
    let mut acc = vec![0.0f32; len];
    for a in args {
        let t = a.as_tensor()?;
        if t.len() != len {
            return Err(Error::Dataflow(format!(
                "mean_stats: inconsistent vector lengths {} vs {len}",
                t.len()
            )));
        }
        for (s, v) in acc.iter_mut().zip(t.data()) {
            *s += v;
        }
    }
    let n = args.len() as f32;
    for s in &mut acc {
        *s /= n;
    }
    Ok(vec![Value::Tensor(HostTensor::new(vec![len], acc)?)])
}

/// The generic image-analysis registry (all CPU-only variants).
pub fn generic_registry() -> OpRegistry {
    let mut r = OpRegistry::new();
    for spec in [
        OpSpec::cpu("grayscale", 1, grayscale),
        OpSpec::cpu("invert", 1, invert),
        OpSpec::cpu("gauss3", 1, gauss3),
        OpSpec::cpu("sobel", 1, sobel),
        OpSpec::cpu("binarize", 1, binarize),
        OpSpec::cpu("cc_label", 1, cc_label),
        OpSpec::cpu("region_stats", 1, region_stats),
        OpSpec::cpu("mean_stats", 1, mean_stats),
    ] {
        r.register(spec).expect("generic op names are unique");
    }
    r
}

/// The cell-stats workflow as data: the JSON form consumed by
/// [`workflow_from_str`] against [`generic_registry`].
pub const CELL_STATS_JSON: &str = r#"{
    "name": "cell-stats",
    "stages": [
        {
            "name": "detect",
            "kind": "per_chunk",
            "inputs": ["chunk"],
            "ops": [
                { "op": "grayscale",    "inputs": [ {"input": 0} ] },
                { "op": "invert",       "inputs": [ {"op": "grayscale"} ] },
                { "op": "gauss3",       "inputs": [ {"op": "invert"} ] },
                { "op": "binarize",     "inputs": [ {"op": "gauss3"}, {"param": 140.0} ] },
                { "op": "cc_label",     "inputs": [ {"op": "binarize"} ] },
                { "op": "region_stats", "inputs": [ {"op": "cc_label"} ] }
            ],
            "outputs": [ {"op": "cc_label"}, {"op": "region_stats"} ]
        },
        {
            "name": "aggregate",
            "kind": "reduce",
            "inputs": [ {"stage": "detect", "output": 1} ],
            "ops": [ { "op": "mean_stats", "inputs": "all" } ],
            "outputs": [ {"op": "mean_stats"} ]
        }
    ]
}"#;

/// Load the cell-stats workflow from its JSON description.
pub fn cell_stats_workflow() -> Result<Workflow> {
    workflow_from_str(CELL_STATS_JSON, Arc::new(generic_registry()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthConfig, TileSynthesizer};
    use crate::dataflow::{run_stage_serial, StageKind};

    fn tile(seed: u64) -> Value {
        let synth = TileSynthesizer::new(SynthConfig::for_tile_size(64, 9));
        Value::Tensor(synth.tissue_tile(seed).to_tensor())
    }

    #[test]
    fn workflow_loads_from_json_and_validates() {
        let wf = cell_stats_workflow().unwrap();
        assert_eq!(wf.name, "cell-stats");
        assert_eq!(wf.stages.len(), 2);
        assert_eq!(wf.stages[1].kind, StageKind::Reduce);
        assert_eq!(wf.stage_index("aggregate"), Some(1));
    }

    #[test]
    fn detect_stage_finds_blobs_on_synthetic_tiles() {
        let wf = cell_stats_workflow().unwrap();
        let outs = run_stage_serial(&wf.stages[0], &[tile(0)]).unwrap();
        assert_eq!(outs.len(), 2);
        let stats = outs[1].as_tensor().unwrap();
        assert_eq!(stats.shape(), &[4]);
        assert!(stats.data()[0] >= 1.0, "expected at least one region");
        assert!(stats.data()[3] > 0.0 && stats.data()[3] < 1.0, "coverage in (0,1)");
    }

    #[test]
    fn mean_stats_averages_vectors() {
        let a = Value::Tensor(HostTensor::new(vec![2], vec![2.0, 4.0]).unwrap());
        let b = Value::Tensor(HostTensor::new(vec![2], vec![4.0, 8.0]).unwrap());
        let m = mean_stats(&[a, b]).unwrap();
        assert_eq!(m[0].as_tensor().unwrap().data(), &[3.0, 6.0]);
        assert!(mean_stats(&[]).is_err());
    }

    #[test]
    fn region_stats_on_empty_mask_is_zero() {
        let empty = Value::Tensor(Gray::zeros(8, 8).to_tensor());
        let s = region_stats(&[empty]).unwrap();
        assert_eq!(s[0].as_tensor().unwrap().data(), &[0.0, 0.0, 0.0, 0.0]);
    }
}
