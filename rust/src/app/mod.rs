//! The example application (paper §II, Fig. 1): whole-slide-image nuclear
//! segmentation + feature computation, assembled as a hierarchical
//! two-level workflow over the `htap` middleware.
//!
//! * Stage "segmentation": RBC detection, Morph. Open, ReconToNuclei,
//!   FillHolles, AreaThreshold, Pre-Watershed, Watershed, BWLabel — each a
//!   fine-grain operation with a CPU variant ([`ops`], rust imgproc) and an
//!   accelerator variant (AOT artifact via PJRT).
//! * Stage "features": the fused tile-level feature graph (deconvolution,
//!   smoothing, gradients, statistics) + per-object morphometry + Haralick
//!   texture (CPU-only, irregular).
//! * Optional stage "classification" (`Reduce`): k-means over all tiles'
//!   feature vectors — the paper's future-work MapReduce stage.
//!
//! All operations live in the central [`OpRegistry`] returned by
//! [`registry`], each carrying its function variant and the calibrated
//! Fig. 7 profile ([`profile`]); the workflow itself is assembled through
//! the typed [`WorkflowBuilder`].  A non-WSI workload built on the same
//! API lives in [`generic`].

pub mod classify;
pub mod generic;
pub mod ops;
pub mod profile;

use crate::dataflow::{param, OpRegistry, OpSpec, StageKind, Workflow, WorkflowBuilder};
use crate::runtime::Value;
use crate::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Tunable analysis parameters (thresholds scale with tile size).
#[derive(Debug, Clone)]
pub struct AppParams {
    pub tile_size: usize,
    /// h-dome height for nucleus candidate detection
    pub hdome_h: f32,
    /// dome threshold
    pub dome_thresh: f32,
    /// component area band
    pub area_lo: f32,
    pub area_hi: f32,
    /// eosin/hema ratio for RBC detection
    pub rbc_ratio: f32,
    /// edge threshold in the feature stage
    pub edge_thresh: f32,
}

impl AppParams {
    pub fn for_tile_size(tile_size: usize) -> Self {
        let scale = (tile_size as f32 / 64.0).max(0.25);
        AppParams {
            tile_size,
            hdome_h: 20.0,
            dome_thresh: 5.0,
            area_lo: 6.0 * scale * scale,
            area_hi: 2000.0 * scale * scale,
            rbc_ratio: 1.2,
            edge_thresh: 30.0,
        }
    }
}

/// Attach the spec's calibrated Fig. 7 profile (neutral when uncalibrated).
fn profiled(spec: OpSpec) -> OpSpec {
    match profile::entry(&spec.name) {
        Some(e) => spec.with_profile(e.speedup, e.transfer_impact, e.cpu_fraction),
        None => spec,
    }
}

/// An [`OpSpec`] with the calibrated profile and a same-named artifact.
fn hybrid_op(name: &str, n_outputs: usize, f: fn(&[Value]) -> Result<Vec<Value>>) -> OpSpec {
    profiled(OpSpec::hybrid(name, n_outputs, f, name))
}

/// A CPU-only [`OpSpec`] with the calibrated profile.
fn cpu_op(name: &str, n_outputs: usize, f: fn(&[Value]) -> Result<Vec<Value>>) -> OpSpec {
    profiled(OpSpec::cpu(name, n_outputs, f))
}

/// The WSI operation registry: every paper Table I operation (plus the
/// extension ops with standalone artifacts), with function variants and
/// the calibrated Fig. 7 performance profile attached.
pub fn registry() -> OpRegistry {
    let mut r = OpRegistry::new();
    for spec in [
        cpu_op("hema_prep", 1, ops::hema_prep),
        hybrid_op("rbc_detect", 1, ops::rbc_detect),
        hybrid_op("morph_open", 1, ops::morph_open),
        hybrid_op("recon_to_nuclei", 1, ops::recon_to_nuclei),
        hybrid_op("fill_holes", 1, ops::fill_holes),
        hybrid_op("area_threshold", 1, ops::area_threshold),
        hybrid_op("bwlabel", 1, ops::bwlabel),
        hybrid_op("pre_watershed", 2, ops::pre_watershed),
        hybrid_op("watershed", 1, ops::watershed_op),
        hybrid_op("feature_graph", 4, ops::feature_graph),
        cpu_op("object_features", 1, ops::object_features),
        cpu_op("haralick", 1, ops::haralick_op),
        // extension ops with standalone artifacts / CPU members
        hybrid_op("distance", 1, ops::distance_op),
        hybrid_op("morph_recon", 1, ops::morph_recon),
        cpu_op("canny", 1, ops::canny_op),
        cpu_op("kmeans", 2, classify::classify_tiles),
    ] {
        r.register(spec).expect("WSI op names are unique");
    }
    r
}

/// Build the **pipelined** two-stage workflow (optionally + classification)
/// over a caller-supplied registry.
///
/// Segmentation op wiring (stage input 0 = RGB tile):
/// ```text
///   rgb ─┬─ hema_prep ── morph_open ── recon_to_nuclei ── fill_holes ──
///        │                             area_threshold ─┬─ bwlabel   (out 2)
///        │                                             ├─ pre_watershed ── watershed (out 0)
///        └─ rbc_detect (out 1)
/// ```
pub fn build_workflow_with(
    registry: Arc<OpRegistry>,
    params: &AppParams,
    with_classification: bool,
) -> Result<Workflow> {
    let p = params;
    let mut wb = WorkflowBuilder::with_shared_registry("wsi-analysis", registry);

    let mut seg = wb.stage("segmentation", StageKind::PerChunk);
    let rgb = seg.input_chunk();
    // cheap preprocessing (CPU-only; paper stage 1)
    let hema = seg.add_op("hema_prep", &[rgb.clone()])?;
    // RBC detection (side chain)
    let rbc = seg.add_op("rbc_detect", &[rgb, param(p.rbc_ratio)])?;
    let opened = seg.add_op("morph_open", &[hema.out()])?;
    // reconstruction-based candidate detection
    let cand = seg.add_op(
        "recon_to_nuclei",
        &[opened.out(), param(p.hdome_h), param(p.dome_thresh)],
    )?;
    let filled = seg.add_op("fill_holes", &[cand.out()])?;
    let kept = seg.add_op(
        "area_threshold",
        &[filled.out(), param(p.area_lo), param(p.area_hi)],
    )?;
    let components = seg.add_op("bwlabel", &[kept.out()])?;
    // distance + markers, then the watershed split
    let pw = seg.add_op("pre_watershed", &[kept.out()])?;
    let nuclei = seg.add_op("watershed", &[pw.output(0), pw.output(1), kept.out()])?;
    seg.export(nuclei.out())?; // 0: nucleus labels
    seg.export(rbc.out())?; // 1: rbc mask
    seg.export(components.out())?; // 2: component labels
    let seg = wb.add_stage(seg)?;

    let mut feat = wb.stage("features", StageKind::PerChunk);
    let rgb = feat.input_chunk();
    let labels = feat.input_upstream(seg.output(0));
    // fused tile-level feature graph
    let fg = feat.add_op("feature_graph", &[rgb, param(p.edge_thresh)])?;
    // per-object morphometry (irregular, CPU-only)
    let objf = feat.add_op(
        "object_features",
        &[labels.clone(), fg.output(0), fg.output(1), fg.output(2)],
    )?;
    // Haralick texture over tissue (CPU-only)
    let har = feat.add_op("haralick", &[fg.output(0), labels])?;
    feat.export(fg.output(3))?; // 0: 41-stats vector
    feat.export(objf.out())?; // 1: object features
    feat.export(har.out())?; // 2: haralick
    let feat = wb.add_stage(feat)?;

    if with_classification {
        let mut cls = wb.stage("classification", StageKind::Reduce);
        cls.input_upstream(feat.output(0));
        // Reduce stage: the WRM passes ALL stage inputs to the op.
        let km = cls.add_reduce_op("kmeans")?;
        cls.export(km.output(0))?;
        cls.export(km.output(1))?;
        wb.add_stage(cls)?;
    }
    wb.build()
}

/// Build the pipelined WSI workflow over the default [`registry`].
pub fn build_workflow(params: &AppParams, with_classification: bool) -> Workflow {
    build_workflow_with(Arc::new(registry()), params, with_classification)
        .expect("the WSI pipeline is statically valid")
}

/// The non-pipelined (monolithic) version for the Fig. 9 comparison: each
/// stage folded into a single task with the time-blended speedup.
pub fn build_monolithic(params: &AppParams, with_classification: bool) -> Workflow {
    let wf = build_workflow(params, with_classification);
    let seg_blend = profile::blended_speedup(&[
        "hema_prep",
        "rbc_detect",
        "morph_open",
        "recon_to_nuclei",
        "fill_holes",
        "area_threshold",
        "bwlabel",
        "pre_watershed",
        "watershed",
    ]);
    let feat_blend = profile::blended_speedup(&["feature_graph", "object_features", "haralick"]);
    let mut blends = vec![seg_blend, feat_blend];
    if with_classification {
        blends.push(1.0);
    }
    wf.monolithic(&blends).expect("stage count matches")
}

/// Bindings of `@stage:<name>` tags to fused artifacts (monolithic mode).
pub fn stage_bindings() -> HashMap<String, String> {
    let mut m = HashMap::new();
    m.insert("segmentation".to_string(), "segment_tile".to_string());
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthConfig, TileSynthesizer};
    use crate::dataflow::run_stage_serial;
    use crate::imgproc::Gray;

    #[test]
    fn workflow_validates() {
        let wf = build_workflow(&AppParams::for_tile_size(64), true);
        wf.validate().unwrap();
        assert_eq!(wf.stages.len(), 3);
        assert_eq!(wf.stages[0].ops.len(), 9);
        assert_eq!(wf.stage_index("classification"), Some(2));
    }

    #[test]
    fn monolithic_validates_and_blends() {
        let wf = build_monolithic(&AppParams::for_tile_size(64), false);
        wf.validate().unwrap();
        assert_eq!(wf.total_ops(), 2);
        let seg = &wf.stages[0].ops[0];
        assert!(seg.speedup > 1.0 && seg.speedup < 15.0);
        assert_eq!(seg.variant.gpu_artifact.as_deref(), None); // hema_prep is CPU-only
    }

    #[test]
    fn serial_pipelined_segmentation_segments_synthetic_tile() {
        let params = AppParams::for_tile_size(32);
        let wf = build_workflow(&params, false);
        let synth = TileSynthesizer::new(SynthConfig::small());
        let tile = Value::Tensor(synth.tissue_tile(3).to_tensor());
        let outs = run_stage_serial(&wf.stages[0], &[tile]).unwrap();
        assert_eq!(outs.len(), 3);
        let labels = Gray::from_tensor(outs[0].as_tensor().unwrap()).unwrap();
        let n = labels.px.iter().fold(0.0f32, |a, &b| a.max(b)) as usize;
        assert!(n >= 1, "no nuclei segmented");
    }

    #[test]
    fn pipelined_equals_monolithic_on_cpu() {
        // The Fig. 9 comparison requires both versions compute the same thing.
        let params = AppParams::for_tile_size(32);
        let pipe = build_workflow(&params, false);
        let mono = build_monolithic(&params, false);
        let synth = TileSynthesizer::new(SynthConfig::small());
        let tile = Value::Tensor(synth.tissue_tile(5).to_tensor());
        let a = run_stage_serial(&pipe.stages[0], &[tile.clone()]).unwrap();
        let b = run_stage_serial(&mono.stages[0], &[tile]).unwrap();
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
    }

    #[test]
    fn every_table1_op_is_present() {
        let wf = build_workflow(&AppParams::for_tile_size(64), false);
        let names: Vec<&str> =
            wf.stages.iter().flat_map(|s| s.ops.iter().map(|o| o.name.as_str())).collect();
        for expected in [
            "rbc_detect",
            "morph_open",
            "recon_to_nuclei",
            "area_threshold",
            "fill_holes",
            "pre_watershed",
            "watershed",
            "bwlabel",
            "feature_graph",
        ] {
            assert!(names.contains(&expected), "missing {expected}");
        }
    }

    #[test]
    fn speedups_come_from_profile() {
        let wf = build_workflow(&AppParams::for_tile_size(64), false);
        let ws = wf.stages[0].ops.iter().find(|o| o.name == "watershed").unwrap();
        assert_eq!(ws.speedup, profile::speedup_of("watershed"));
    }

    #[test]
    fn registry_carries_profiles_and_variants() {
        let r = registry();
        for e in profile::PROFILE {
            let spec = r.get(e.name).unwrap();
            assert_eq!(spec.speedup, e.speedup, "{}", e.name);
            assert_eq!(spec.transfer_impact, e.transfer_impact, "{}", e.name);
            assert_eq!(spec.cpu_fraction, e.cpu_fraction, "{}", e.name);
        }
        assert!(r.get("watershed").unwrap().variant.has_gpu());
        assert!(!r.get("hema_prep").unwrap().variant.has_gpu());
        assert_eq!(r.get("kmeans").unwrap().n_outputs, 2);
    }

    #[test]
    fn unknown_op_in_custom_workflow_fails_eagerly() {
        let reg = Arc::new(registry());
        let wb = WorkflowBuilder::with_shared_registry("bad", reg);
        let mut s = wb.stage("s", StageKind::PerChunk);
        let chunk = s.input_chunk();
        assert!(s.add_op("not_a_wsi_op", &[chunk]).is_err());
    }
}
