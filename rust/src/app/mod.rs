//! The example application (paper §II, Fig. 1): whole-slide-image nuclear
//! segmentation + feature computation, assembled as a hierarchical
//! two-level workflow over the `htap` middleware.
//!
//! * Stage "segmentation": RBC detection, Morph. Open, ReconToNuclei,
//!   FillHolles, AreaThreshold, Pre-Watershed, Watershed, BWLabel — each a
//!   fine-grain operation with a CPU variant ([`ops`], rust imgproc) and an
//!   accelerator variant (AOT artifact via PJRT).
//! * Stage "features": the fused tile-level feature graph (deconvolution,
//!   smoothing, gradients, statistics) + per-object morphometry + Haralick
//!   texture (CPU-only, irregular).
//! * Optional stage "classification" (`Reduce`): k-means over all tiles'
//!   feature vectors — the paper's future-work MapReduce stage.

pub mod classify;
pub mod ops;
pub mod profile;

use crate::dataflow::{FunctionVariant, OpDef, PortRef, StageDef, StageInput, StageKind, Workflow};
use crate::runtime::Value;
use std::collections::HashMap;

/// Tunable analysis parameters (thresholds scale with tile size).
#[derive(Debug, Clone)]
pub struct AppParams {
    pub tile_size: usize,
    /// h-dome height for nucleus candidate detection
    pub hdome_h: f32,
    /// dome threshold
    pub dome_thresh: f32,
    /// component area band
    pub area_lo: f32,
    pub area_hi: f32,
    /// eosin/hema ratio for RBC detection
    pub rbc_ratio: f32,
    /// edge threshold in the feature stage
    pub edge_thresh: f32,
}

impl AppParams {
    pub fn for_tile_size(tile_size: usize) -> Self {
        let scale = (tile_size as f32 / 64.0).max(0.25);
        AppParams {
            tile_size,
            hdome_h: 20.0,
            dome_thresh: 5.0,
            area_lo: 6.0 * scale * scale,
            area_hi: 2000.0 * scale * scale,
            rbc_ratio: 1.2,
            edge_thresh: 30.0,
        }
    }
}

fn op(
    name: &str,
    cpu: impl Fn(&[Value]) -> crate::Result<Vec<Value>> + Send + Sync + 'static,
    artifact: Option<&str>,
    inputs: Vec<PortRef>,
    n_outputs: usize,
) -> OpDef {
    OpDef {
        name: name.to_string(),
        variant: match artifact {
            Some(a) => FunctionVariant::hybrid(cpu, a),
            None => FunctionVariant::cpu_only(cpu),
        },
        inputs,
        n_outputs,
        speedup: profile::speedup_of(name),
        transfer_impact: profile::transfer_impact_of(name),
    }
}

/// Build the **pipelined** two-stage workflow (optionally + classification).
///
/// Segmentation op wiring (stage input 0 = RGB tile):
/// ```text
///   rgb ─┬─ hema_prep ── morph_open ── recon_to_nuclei ── fill_holes ──
///        │                             area_threshold ─┬─ bwlabel   (out 2)
///        │                                             ├─ pre_watershed ── watershed (out 0)
///        └─ rbc_detect (out 1)
/// ```
pub fn build_workflow(params: &AppParams, with_classification: bool) -> Workflow {
    let p = params.clone();
    let mut wf = Workflow::new("wsi-analysis");

    let seg = StageDef {
        name: "segmentation".into(),
        kind: StageKind::PerChunk,
        inputs: vec![StageInput::Chunk],
        ops: vec![
            // 0: cheap preprocessing (CPU-only; paper stage 1)
            op("hema_prep", ops::hema_prep, None, vec![PortRef::StageInput(0)], 1),
            // 1: RBC detection (side chain)
            op(
                "rbc_detect",
                ops::rbc_detect,
                Some("rbc_detect"),
                vec![PortRef::StageInput(0), PortRef::Param(Value::Scalar(p.rbc_ratio))],
                1,
            ),
            // 2: morphological open
            op(
                "morph_open",
                ops::morph_open,
                Some("morph_open"),
                vec![PortRef::Op { op: 0, output: 0 }],
                1,
            ),
            // 3: reconstruction-based candidate detection
            op(
                "recon_to_nuclei",
                ops::recon_to_nuclei,
                Some("recon_to_nuclei"),
                vec![
                    PortRef::Op { op: 2, output: 0 },
                    PortRef::Param(Value::Scalar(p.hdome_h)),
                    PortRef::Param(Value::Scalar(p.dome_thresh)),
                ],
                1,
            ),
            // 4: fill holes
            op(
                "fill_holes",
                ops::fill_holes,
                Some("fill_holes"),
                vec![PortRef::Op { op: 3, output: 0 }],
                1,
            ),
            // 5: area threshold
            op(
                "area_threshold",
                ops::area_threshold,
                Some("area_threshold"),
                vec![
                    PortRef::Op { op: 4, output: 0 },
                    PortRef::Param(Value::Scalar(p.area_lo)),
                    PortRef::Param(Value::Scalar(p.area_hi)),
                ],
                1,
            ),
            // 6: BWLabel (exported component labels)
            op(
                "bwlabel",
                ops::bwlabel,
                Some("bwlabel"),
                vec![PortRef::Op { op: 5, output: 0 }],
                1,
            ),
            // 7: pre-watershed (distance + markers)
            op(
                "pre_watershed",
                ops::pre_watershed,
                Some("pre_watershed"),
                vec![PortRef::Op { op: 5, output: 0 }],
                2,
            ),
            // 8: watershed
            op(
                "watershed",
                ops::watershed_op,
                Some("watershed"),
                vec![
                    PortRef::Op { op: 7, output: 0 },
                    PortRef::Op { op: 7, output: 1 },
                    PortRef::Op { op: 5, output: 0 },
                ],
                1,
            ),
        ],
        outputs: vec![
            PortRef::Op { op: 8, output: 0 }, // nucleus labels
            PortRef::Op { op: 1, output: 0 }, // rbc mask
            PortRef::Op { op: 6, output: 0 }, // component labels
        ],
    };
    let seg_idx = wf.add_stage(seg);

    let feat = StageDef {
        name: "features".into(),
        kind: StageKind::PerChunk,
        inputs: vec![
            StageInput::Chunk,
            StageInput::Upstream { stage: seg_idx, output: 0 },
        ],
        ops: vec![
            // 0: fused tile-level feature graph
            op(
                "feature_graph",
                ops::feature_graph,
                Some("feature_graph"),
                vec![PortRef::StageInput(0), PortRef::Param(Value::Scalar(p.edge_thresh))],
                4,
            ),
            // 1: per-object morphometry (irregular, CPU-only)
            op(
                "object_features",
                ops::object_features,
                None,
                vec![
                    PortRef::StageInput(1),
                    PortRef::Op { op: 0, output: 0 },
                    PortRef::Op { op: 0, output: 1 },
                    PortRef::Op { op: 0, output: 2 },
                ],
                1,
            ),
            // 2: Haralick texture over tissue (CPU-only)
            op(
                "haralick",
                ops::haralick_op,
                None,
                vec![PortRef::Op { op: 0, output: 0 }, PortRef::StageInput(1)],
                1,
            ),
        ],
        outputs: vec![
            PortRef::Op { op: 0, output: 3 }, // 41-stats vector
            PortRef::Op { op: 1, output: 0 }, // object features
            PortRef::Op { op: 2, output: 0 }, // haralick
        ],
    };
    let feat_idx = wf.add_stage(feat);

    if with_classification {
        wf.add_stage(StageDef {
            name: "classification".into(),
            kind: StageKind::Reduce,
            inputs: vec![StageInput::Upstream { stage: feat_idx, output: 0 }],
            ops: vec![OpDef {
                name: "kmeans".into(),
                variant: FunctionVariant::cpu_only(classify::classify_tiles),
                // Reduce stage: the WRM passes ALL stage inputs to the op.
                inputs: vec![],
                n_outputs: 2,
                speedup: 1.0,
                transfer_impact: 0.0,
            }],
            outputs: vec![PortRef::Op { op: 0, output: 0 }, PortRef::Op { op: 0, output: 1 }],
        });
    }
    wf
}

/// The non-pipelined (monolithic) version for the Fig. 9 comparison: each
/// stage folded into a single task with the time-blended speedup.
pub fn build_monolithic(params: &AppParams, with_classification: bool) -> Workflow {
    let wf = build_workflow(params, with_classification);
    let seg_blend = profile::blended_speedup(&[
        "hema_prep",
        "rbc_detect",
        "morph_open",
        "recon_to_nuclei",
        "fill_holes",
        "area_threshold",
        "bwlabel",
        "pre_watershed",
        "watershed",
    ]);
    let feat_blend = profile::blended_speedup(&["feature_graph", "object_features", "haralick"]);
    let mut blends = vec![seg_blend, feat_blend];
    if with_classification {
        blends.push(1.0);
    }
    wf.monolithic(&blends).expect("stage count matches")
}

/// Bindings of `@stage:<name>` tags to fused artifacts (monolithic mode).
pub fn stage_bindings() -> HashMap<String, String> {
    let mut m = HashMap::new();
    m.insert("segmentation".to_string(), "segment_tile".to_string());
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::run_stage_serial;
    use crate::data::{SynthConfig, TileSynthesizer};
    use crate::imgproc::Gray;

    #[test]
    fn workflow_validates() {
        let wf = build_workflow(&AppParams::for_tile_size(64), true);
        wf.validate().unwrap();
        assert_eq!(wf.stages.len(), 3);
        assert_eq!(wf.stages[0].ops.len(), 9);
    }

    #[test]
    fn monolithic_validates_and_blends() {
        let wf = build_monolithic(&AppParams::for_tile_size(64), false);
        wf.validate().unwrap();
        assert_eq!(wf.total_ops(), 2);
        let seg = &wf.stages[0].ops[0];
        assert!(seg.speedup > 1.0 && seg.speedup < 15.0);
        assert_eq!(seg.variant.gpu_artifact.as_deref(), None); // hema_prep is CPU-only
    }

    #[test]
    fn serial_pipelined_segmentation_segments_synthetic_tile() {
        let params = AppParams::for_tile_size(32);
        let wf = build_workflow(&params, false);
        let synth = TileSynthesizer::new(SynthConfig::small());
        let tile = Value::Tensor(synth.tissue_tile(3).to_tensor());
        let outs = run_stage_serial(&wf.stages[0], &[tile]).unwrap();
        assert_eq!(outs.len(), 3);
        let labels = Gray::from_tensor(outs[0].as_tensor().unwrap()).unwrap();
        let n = labels.px.iter().fold(0.0f32, |a, &b| a.max(b)) as usize;
        assert!(n >= 1, "no nuclei segmented");
    }

    #[test]
    fn pipelined_equals_monolithic_on_cpu() {
        // The Fig. 9 comparison requires both versions compute the same thing.
        let params = AppParams::for_tile_size(32);
        let pipe = build_workflow(&params, false);
        let mono = build_monolithic(&params, false);
        let synth = TileSynthesizer::new(SynthConfig::small());
        let tile = Value::Tensor(synth.tissue_tile(5).to_tensor());
        let a = run_stage_serial(&pipe.stages[0], &[tile.clone()]).unwrap();
        let b = run_stage_serial(&mono.stages[0], &[tile]).unwrap();
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
    }

    #[test]
    fn every_table1_op_is_present() {
        let wf = build_workflow(&AppParams::for_tile_size(64), false);
        let names: Vec<&str> =
            wf.stages.iter().flat_map(|s| s.ops.iter().map(|o| o.name.as_str())).collect();
        for expected in [
            "rbc_detect",
            "morph_open",
            "recon_to_nuclei",
            "area_threshold",
            "fill_holes",
            "pre_watershed",
            "watershed",
            "bwlabel",
            "feature_graph",
        ] {
            assert!(names.contains(&expected), "missing {expected}");
        }
    }

    #[test]
    fn speedups_come_from_profile() {
        let wf = build_workflow(&AppParams::for_tile_size(64), false);
        let ws = wf.stages[0].ops.iter().find(|o| o.name == "watershed").unwrap();
        assert_eq!(ws.speedup, profile::speedup_of("watershed"));
    }
}
