//! Classification stage: k-means over per-tile feature vectors.
//!
//! The paper's fourth stage aggregates feature vectors and classifies
//! images/patients with machine-learning methods such as k-means [31]; the
//! conclusions name integrating it as future work.  We implement it as a
//! `Reduce` stage (Fig. 3's second instantiation style): the Manager feeds
//! it the stats vectors of *all* tiles, and it clusters them.

use crate::runtime::{HostTensor, Value};
use crate::testing::Rng;
use crate::{Error, Result};

/// k-means result: centroids (k x d) and per-point assignment.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    pub centroids: Vec<Vec<f32>>,
    pub assignment: Vec<usize>,
    pub inertia: f32,
}

/// Lloyd's algorithm with deterministic seeding (k-means++ style greedy
/// farthest-point init on a fixed RNG).
pub fn kmeans(points: &[Vec<f32>], k: usize, iters: usize, seed: u64) -> Result<KMeansResult> {
    if points.is_empty() {
        return Err(Error::Dataflow("kmeans: no points".into()));
    }
    let d = points[0].len();
    if points.iter().any(|p| p.len() != d) {
        return Err(Error::Dataflow("kmeans: ragged points".into()));
    }
    let k = k.min(points.len()).max(1);
    let mut rng = Rng::new(seed);
    // farthest-point init
    let mut centroids: Vec<Vec<f32>> = vec![points[rng.below(points.len())].clone()];
    while centroids.len() < k {
        let (best, _) = points
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let dmin = centroids.iter().map(|c| dist2(p, c)).fold(f32::INFINITY, f32::min);
                (i, dmin)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        centroids.push(points[best].clone());
    }
    let mut assignment = vec![0usize; points.len()];
    for _ in 0..iters {
        // assign
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let (best, _) = centroids
                .iter()
                .enumerate()
                .map(|(j, c)| (j, dist2(p, c)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // update
        let mut sums = vec![vec![0.0f32; d]; k];
        let mut counts = vec![0usize; k];
        for (p, &a) in points.iter().zip(&assignment) {
            counts[a] += 1;
            for (s, v) in sums[a].iter_mut().zip(p) {
                *s += v;
            }
        }
        for j in 0..k {
            if counts[j] > 0 {
                for s in sums[j].iter_mut() {
                    *s /= counts[j] as f32;
                }
                centroids[j] = sums[j].clone();
            }
        }
        if !changed {
            break;
        }
    }
    let inertia = points
        .iter()
        .zip(&assignment)
        .map(|(p, &a)| dist2(p, &centroids[a]))
        .sum();
    Ok(KMeansResult { centroids, assignment, inertia })
}

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// The Reduce-stage CPU variant: takes N stats vectors (one Value each),
/// z-normalises the dimensions, clusters into k groups; outputs
/// (assignment [N], centroids [k*d]).
pub fn classify_tiles(args: &[Value]) -> Result<Vec<Value>> {
    let mut points: Vec<Vec<f32>> = Vec::with_capacity(args.len());
    for v in args {
        points.push(v.as_tensor()?.data().to_vec());
    }
    if points.is_empty() {
        return Err(Error::Dataflow("classify: no tiles".into()));
    }
    let d = points[0].len();
    // z-normalise
    for j in 0..d {
        let mean = points.iter().map(|p| p[j]).sum::<f32>() / points.len() as f32;
        let var = points.iter().map(|p| (p[j] - mean) * (p[j] - mean)).sum::<f32>()
            / points.len() as f32;
        let sd = var.sqrt().max(1e-6);
        for p in points.iter_mut() {
            p[j] = (p[j] - mean) / sd;
        }
    }
    let k = 3.min(points.len());
    let res = kmeans(&points, k, 50, 0xC1A55)?;
    let assign: Vec<f32> = res.assignment.iter().map(|&a| a as f32).collect();
    let flat: Vec<f32> = res.centroids.iter().flatten().copied().collect();
    Ok(vec![
        Value::Tensor(HostTensor::new(vec![assign.len()], assign)?),
        Value::Tensor(HostTensor::new(vec![res.centroids.len(), d], flat)?),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster_points() -> Vec<Vec<f32>> {
        let mut pts = Vec::new();
        for i in 0..10 {
            pts.push(vec![0.0 + (i as f32) * 0.01, 0.0]);
            pts.push(vec![10.0 + (i as f32) * 0.01, 10.0]);
        }
        pts
    }

    #[test]
    fn separates_two_clear_clusters() {
        let pts = cluster_points();
        let r = kmeans(&pts, 2, 20, 1).unwrap();
        // points 0,2,4.. belong together
        let a0 = r.assignment[0];
        for i in (0..20).step_by(2) {
            assert_eq!(r.assignment[i], a0);
        }
        assert_ne!(r.assignment[0], r.assignment[1]);
        assert!(r.inertia < 1.0);
    }

    #[test]
    fn k_clamped_to_n_points() {
        let pts = vec![vec![1.0, 2.0]];
        let r = kmeans(&pts, 5, 10, 0).unwrap();
        assert_eq!(r.centroids.len(), 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let pts = cluster_points();
        let a = kmeans(&pts, 2, 20, 7).unwrap();
        let b = kmeans(&pts, 2, 20, 7).unwrap();
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn ragged_input_rejected() {
        let pts = vec![vec![1.0], vec![1.0, 2.0]];
        assert!(kmeans(&pts, 2, 5, 0).is_err());
        assert!(kmeans(&[], 2, 5, 0).is_err());
    }

    #[test]
    fn classify_tiles_outputs_assignment_and_centroids() {
        let vals: Vec<Value> = (0..6)
            .map(|i| {
                let base = if i < 3 { 0.0 } else { 100.0 };
                Value::Tensor(
                    HostTensor::new(vec![4], vec![base, base + 1.0, base, base]).unwrap(),
                )
            })
            .collect();
        let out = classify_tiles(&vals).unwrap();
        let assign = out[0].as_tensor().unwrap();
        assert_eq!(assign.shape(), &[6]);
        // the two groups of tiles get different clusters
        assert_ne!(assign.data()[0], assign.data()[5]);
        assert_eq!(assign.data()[0], assign.data()[1]);
    }
}
