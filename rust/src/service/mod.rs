//! The multi-tenant workflow service: a job table multiplexing many
//! per-job managers over one shared elastic worker pool.
//!
//! The paper's middleware runs one application dataset per deployment;
//! this module is the "millions of users" refactor from ROADMAP.md.  A
//! long-running `htap serve` daemon accepts workflow submissions over the
//! wire (proto v5 `Submit`), compiles each against the op registry, and
//! runs it as a **job**: today's [`Manager`] (re-exported here as
//! [`JobManager`]), one per submitted workflow, under a [`JobTable`] that
//! owns:
//!
//! * **admission control** — at most `max_jobs` jobs run concurrently;
//!   excess submissions queue (FIFO by job id); each tenant may have at
//!   most `tenant_queue_depth` non-terminal jobs at once (excess
//!   submissions are *rejected*, the wire client sees the error);
//! * **weighted fair-share scheduling** — one worker `Request` fans out
//!   across tenants by deficit round-robin: each tenant accumulates
//!   deficit proportional to its weight (the `Submit` priority) every
//!   round and spends it one assignment at a time, so a tenant with a
//!   36k-tile job cannot starve a tenant with a 10-tile job;
//! * **the job lifecycle** — `Queued → Running → Done | Failed |
//!   Cancelled`, surfaced through the `JobStatus` wire API as
//!   [`JobSummary`] rows (progress, per-job locality stats, fair-share
//!   assignment counts);
//! * **service checkpointing** — [`JobTable::snapshot`] captures every
//!   job (journal + catalog via the per-job manager) for
//!   `checkpoint::write_service_checkpoint`, and [`JobTable::restore`]
//!   rebuilds the table on `htap serve --resume`.
//!
//! Stage-instance ids are tagged with the owning job
//! (`gid = job << JOB_SHIFT | local`) so completions route back to the
//! right manager over the same wire messages the single-job protocol
//! uses.  Workers are *job-agnostic*: they see one work source handing
//! out interleaved assignments; the only service-visible change is the
//! `Idle` message ("nothing assignable right now, poll again") because a
//! long-running service must not reuse the empty batch, which means
//! "workflow over, shut down" to a v4 worker.
//!
//! Lock order: the table lock nests *outside* every per-job manager lock
//! (table → manager), and nothing here calls back into the table while
//! holding a manager lock.

use crate::coordinator::checkpoint::JobCheckpoint;
use crate::coordinator::manager::{
    AssignPolicy, Manager, WorkBatch, WorkRequest, WorkSource,
};
use crate::data::staging::WorkerId;
use crate::dataflow::{workflow_from_str, OpRegistry, StageKind, Workflow};
use crate::obs::{self, TraceEvent, UtilRow};
use crate::runtime::sync::{self, Condvar, Mutex};
use crate::runtime::Value;
use crate::{Error, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// The per-job manager: exactly today's [`Manager`], one per submitted
/// workflow.  The alias names the role it plays under the [`JobTable`].
pub use crate::coordinator::manager::Manager as JobManager;

/// Bits reserved for the per-job local instance id.  A job tags every
/// stage-instance id it hands to the shared pool with its job id in the
/// high bits, so completions route back without widening the wire format.
pub const JOB_SHIFT: u32 = 40;
const LOCAL_MASK: u64 = (1u64 << JOB_SHIFT) - 1;

/// Job ids live in the high `64 - JOB_SHIFT` bits; cap them well below
/// that so the tag arithmetic can never collide or overflow.
pub const MAX_JOB_ID: u64 = 1 << 24;

/// Tag a job-local instance id with its owning job.
pub fn tag_instance(job: u64, local: u64) -> u64 {
    (job << JOB_SHIFT) | local
}

/// The owning job of a tagged instance id (0 = single-job mode: the
/// plain [`Manager`] never tags, so legacy ids route nowhere special).
pub fn job_of(instance: u64) -> u64 {
    instance >> JOB_SHIFT
}

/// The job-local instance id of a tagged id.
pub fn local_of(instance: u64) -> u64 {
    instance & LOCAL_MASK
}

/// Job lifecycle (`Queued → Running → Done | Failed | Cancelled`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Admitted, waiting for a run slot (`max_jobs`).
    Queued,
    /// Has a live manager; its instances compete in fair-share.
    Running,
    /// All instances completed; reduce outputs are readable.
    Done,
    /// The manager reported a fatal error.
    Failed,
    /// Cancelled by the operator; nothing was requeued.
    Cancelled,
}

impl JobState {
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "Queued",
            JobState::Running => "Running",
            JobState::Done => "Done",
            JobState::Failed => "Failed",
            JobState::Cancelled => "Cancelled",
        }
    }

    pub fn parse(s: &str) -> Option<JobState> {
        match s {
            "Queued" => Some(JobState::Queued),
            "Running" => Some(JobState::Running),
            "Done" => Some(JobState::Done),
            "Failed" => Some(JobState::Failed),
            "Cancelled" => Some(JobState::Cancelled),
            _ => None,
        }
    }

    /// Terminal states never transition again and hold no manager.
    pub fn terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// One row of the job-status API (proto v5 `JobReport`): lifecycle,
/// progress, fair-share assignment count and per-job locality stats.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobSummary {
    pub job: u64,
    pub tenant: String,
    /// [`JobState::name`] — stringly so the wire codec needs no enum.
    pub state: String,
    /// Workflow name (from the submitted JSON).
    pub workflow: String,
    pub done: u64,
    pub total: u64,
    /// Assignments handed out for this job (fair-share accounting).
    pub assigned: u64,
    /// Per-job locality: assignments to the worker that staged the chunk.
    pub hits: u64,
    /// Per-job locality: cold-chunk assignments.
    pub cold: u64,
    /// Per-job locality: steals from another worker's staged set.
    pub steals: u64,
    /// The tenant weight this job was submitted with.
    pub priority: u32,
    /// Ops executed for this job, from the merged trace rollup (0 when
    /// no worker traced).
    pub ops: u64,
    /// Execution time summed over those ops, µs (trace rollup).
    pub busy_us: u64,
}

/// What the network layer serves: both the single-job [`Manager`]
/// (`htap manager`) and the multi-job [`JobTable`] (`htap serve`)
/// implement this, so `net::ManagerServer` is one code path.  The
/// service-only methods default to rejection — a v5 client submitting to
/// a single-job manager gets a clean error, not a protocol wedge.
pub trait Endpoint: Send + Sync {
    /// Hand out up to `req.capacity` assignments.  Single-job endpoints
    /// block until work is available and use the empty batch for
    /// "workflow over"; service endpoints never block and return
    /// `idle = true` when nothing is assignable right now.
    fn request_work(&self, req: &WorkRequest) -> WorkBatch;

    /// Fold a finished stage instance back in (service: tagged id).
    fn complete(&self, instance: u64, outputs: Vec<Value>);

    /// A worker reported a fatal error (service: fails every running job).
    fn fail(&self, msg: String);

    fn register_worker(&self, worker: WorkerId, lease_ms: u64);
    fn heartbeat_worker(&self, worker: WorkerId);

    /// Clean departure (worker drained): deregister + purge.
    fn expire_worker(&self, worker: WorkerId) -> usize;

    /// Connection died: forget the worker's staged chunks.
    fn purge_worker(&self, worker: WorkerId) -> usize;

    /// Re-issue leases a dead connection was holding.
    fn requeue_stale(&self, leases: &[u64]) -> usize;

    /// Expire workers that missed their lease; returns `(worker,
    /// requeued)` per expired worker.
    fn sweep_leases(&self) -> Vec<(WorkerId, usize)>;

    /// Block until this endpoint is finished serving (single job: the
    /// workflow completed or failed; service: explicit shutdown).
    fn wait_done(&self);

    /// Submit a workflow (service only).  Returns the new job id.
    fn submit(&self, _tenant: &str, _workflow_json: &str, _priority: u32) -> Result<u64> {
        Err(Error::Scheduler(
            "this manager runs a single workflow and does not accept submissions \
             (start it with `htap serve` for service mode)"
                .into(),
        ))
    }

    /// Cancel a job (service only).
    fn cancel_job(&self, _job: u64) -> Result<()> {
        Err(Error::Scheduler("not a service-mode manager (nothing to cancel)".into()))
    }

    /// Status rows for `job`, or all jobs when `job == 0`.
    fn job_report(&self, _job: u64) -> Vec<JobSummary> {
        Vec::new()
    }

    /// A job's `(tenant, workflow_json)` — workers fetch this to compile
    /// workflows for jobs they haven't seen yet.
    fn job_spec(&self, _job: u64) -> Result<(String, String)> {
        Err(Error::Scheduler("not a service-mode manager (no job specs)".into()))
    }

    /// Merge a worker's drained trace batch (proto v6 `TraceBatch`).
    /// Default drop, so endpoints without a collector stay valid.
    fn trace_batch(&self, _worker: WorkerId, _events: Vec<TraceEvent>) {}

    /// Live per-(worker, job) utilization rows (proto v6 `StatsQuery`,
    /// the `htap top` feed).  Default empty.
    fn utilization(&self) -> Vec<UtilRow> {
        Vec::new()
    }
}

/// The single-job endpoint: `htap manager` serving one workflow.
impl Endpoint for Manager {
    fn request_work(&self, req: &WorkRequest) -> WorkBatch {
        WorkSource::request_work(self, req)
    }

    fn complete(&self, instance: u64, outputs: Vec<Value>) {
        self.complete_instance(instance, outputs)
    }

    fn fail(&self, msg: String) {
        Manager::fail(self, msg)
    }

    fn register_worker(&self, worker: WorkerId, lease_ms: u64) {
        Manager::register_worker(self, worker, lease_ms)
    }

    fn heartbeat_worker(&self, worker: WorkerId) {
        Manager::heartbeat_worker(self, worker)
    }

    fn expire_worker(&self, worker: WorkerId) -> usize {
        Manager::expire_worker(self, worker)
    }

    fn purge_worker(&self, worker: WorkerId) -> usize {
        Manager::purge_worker(self, worker)
    }

    fn requeue_stale(&self, leases: &[u64]) -> usize {
        Manager::requeue_stale(self, leases)
    }

    fn sweep_leases(&self) -> Vec<(WorkerId, usize)> {
        Manager::sweep_leases(self)
    }

    fn wait_done(&self) {
        Manager::wait_done(self)
    }

    fn trace_batch(&self, worker: WorkerId, events: Vec<TraceEvent>) {
        self.ingest_trace(worker, events);
    }

    fn utilization(&self) -> Vec<UtilRow> {
        Manager::utilization(self)
    }
}

/// Stage-instance count a workflow expands to over `n_chunks` chunks.
pub fn total_instances(wf: &Workflow, n_chunks: usize) -> u64 {
    wf.stages
        .iter()
        .map(|s| match s.kind {
            StageKind::PerChunk => n_chunks as u64,
            StageKind::Reduce => 1,
        })
        .sum()
}

/// Render a value the way run summaries print reduce outputs: scalars as
/// shortest round-trip, tensors as shape + FNV-1a of the little-endian
/// payload.  Shared by `htap run`/`htap manager` summaries and the
/// service's per-job announcements, so smoke tests can diff the lines
/// bit-for-bit between single-job and service runs.
pub fn render_value(v: &Value) -> String {
    match v {
        Value::Scalar(s) => format!("{s}"),
        Value::Tensor(t) => {
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for f in t.data() {
                for b in f.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x100_0000_01b3);
                }
            }
            format!("tensor{:?}#{h:016x}", t.shape())
        }
    }
}

/// One submitted workflow and its runtime state.
struct Job {
    id: u64,
    tenant: String,
    priority: u32,
    workflow_json: String,
    workflow: Arc<Workflow>,
    state: JobState,
    /// Live while `Running`; kept after `Done` so reduce outputs stay
    /// readable; dropped on `Failed`/`Cancelled` (frees in-flight state).
    manager: Option<Arc<Manager>>,
    /// Assignments handed out for this job.
    assigned: u64,
    /// Cancel requested: the terminal transition maps the manager error
    /// to `Cancelled` instead of `Failed`.
    cancelled: bool,
    error: Option<String>,
    /// A checkpointed journal + catalog to replay when this job gets its
    /// run slot (`htap serve --resume`).
    pending_restore: Option<(
        Vec<crate::coordinator::manager::CompletionRecord>,
        Vec<(WorkerId, crate::coordinator::manager::ChunkId, crate::data::staging::Tier)>,
    )>,
    /// Progress for manager-less jobs (queued, or terminal after the
    /// manager was dropped / a resume).
    done_hint: u64,
    total_hint: u64,
    /// Locality stats frozen at the terminal transition.
    loc_hint: (u64, u64, u64),
}

impl Job {
    fn summary(&self) -> JobSummary {
        let (done, total, loc) = match &self.manager {
            Some(m) => {
                let (d, t) = m.progress();
                (d as u64, t as u64, m.locality_stats())
            }
            None => (self.done_hint, self.total_hint, self.loc_hint),
        };
        JobSummary {
            job: self.id,
            tenant: self.tenant.clone(),
            state: self.state.name().to_string(),
            workflow: self.workflow.name.clone(),
            done,
            total,
            assigned: self.assigned,
            hits: loc.0,
            cold: loc.1,
            steals: loc.2,
            priority: self.priority,
            // joined in by JobTable::job_report from the merged trace
            ops: 0,
            busy_us: 0,
        }
    }
}

/// Per-tenant fair-share bookkeeping (deficit round-robin).
struct TenantShare {
    /// Submission priority (latest submission wins); the DRR quantum.
    weight: u32,
    /// Unspent assignment credit carried between rounds.
    deficit: u64,
    /// Total assignments granted (the fair-share acceptance metric).
    assigned: u64,
}

struct TableState {
    jobs: BTreeMap<u64, Job>,
    next_job: u64,
    tenants: BTreeMap<String, TenantShare>,
    /// Registered workers and their lease terms, forwarded to every
    /// manager a new job starts with.
    members: HashMap<WorkerId, u64>,
    /// Rotates which tenant a DRR sweep starts from.
    rr_cursor: usize,
    /// Shutdown: request_work answers with a non-idle empty batch so
    /// workers wind down, and `wait_done` returns.
    stop: bool,
}

/// The multi-job service endpoint: admission, fair-share, lifecycle.
pub struct JobTable {
    registry: Arc<OpRegistry>,
    n_chunks: usize,
    policy: AssignPolicy,
    max_jobs: usize,
    tenant_queue_depth: usize,
    /// Print per-job lifecycle + reduce-output announcements.
    announce: AtomicBool,
    /// Enable the completion journal on every manager (checkpointing).
    journal: AtomicBool,
    /// Merge point for worker-shipped trace batches; per-job managers also
    /// collect (membership events), but the service-level rollups and the
    /// `htap top` feed read from here.
    collector: obs::Collector,
    table: Mutex<TableState>,
    cv: Condvar,
}

impl JobTable {
    /// `registry` resolves ops in submitted workflows; every job is
    /// instantiated over the same `n_chunks`-chunk source with the same
    /// assignment `policy`.
    pub fn new(
        registry: Arc<OpRegistry>,
        n_chunks: usize,
        policy: AssignPolicy,
        max_jobs: usize,
        tenant_queue_depth: usize,
    ) -> Arc<JobTable> {
        Arc::new(JobTable {
            registry,
            n_chunks,
            policy,
            max_jobs: max_jobs.max(1),
            tenant_queue_depth: tenant_queue_depth.max(1),
            announce: AtomicBool::new(false),
            journal: AtomicBool::new(false),
            collector: obs::Collector::new(),
            table: Mutex::new(TableState {
                jobs: BTreeMap::new(),
                next_job: 1,
                tenants: BTreeMap::new(),
                members: HashMap::new(),
                rr_cursor: 0,
                stop: false,
            }),
            cv: Condvar::new(),
        })
    }

    /// Print lifecycle transitions (stderr) and reduce outputs (stdout).
    pub fn set_announce(&self, on: bool) {
        self.announce.store(on, Ordering::Release);
    }

    /// Journal completions on every job's manager so [`JobTable::snapshot`]
    /// is replayable.  Call before any submission.
    pub fn enable_journal(&self) {
        self.journal.store(true, Ordering::Release);
    }

    /// Stop serving: workers get shut-down batches, `wait_done` returns.
    pub fn shutdown(&self) {
        let mut t = sync::lock_clean(&self.table);
        t.stop = true;
        drop(t);
        self.cv.notify_all();
    }

    /// Block until `job` reaches a terminal state (or disappears).
    pub fn wait_job(&self, job: u64) {
        let mut t = sync::lock_clean(&self.table);
        loop {
            match t.jobs.get(&job) {
                Some(j) if !j.state.terminal() => {}
                _ => return,
            }
            t = match self.cv.wait(t) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    /// Reduce outputs of a completed job's stage (by name), mirroring
    /// [`Manager::reduce_outputs`].
    pub fn reduce_outputs(&self, job: u64, stage: &str) -> Option<Vec<Value>> {
        let mgr = {
            let t = sync::lock_clean(&self.table);
            t.jobs.get(&job).and_then(|j| j.manager.clone())
        };
        mgr.and_then(|m| m.reduce_outputs(stage))
    }

    /// The service-wide trace merge point (worker batches land here via
    /// [`Endpoint::trace_batch`]); `htap serve --trace-out` exports it.
    pub fn collector(&self) -> &obs::Collector {
        &self.collector
    }

    /// Per-tenant `(weight, total assignments granted)` — the fair-share
    /// acceptance metric.
    pub fn tenant_assignments(&self) -> Vec<(String, u32, u64)> {
        let t = sync::lock_clean(&self.table);
        t.tenants.iter().map(|(n, s)| (n.clone(), s.weight, s.assigned)).collect()
    }

    /// Managers of currently-running jobs (for delta broadcast / sweeps).
    fn running_managers(&self) -> Vec<Arc<Manager>> {
        let t = sync::lock_clean(&self.table);
        t.jobs
            .values()
            .filter(|j| j.state == JobState::Running)
            .filter_map(|j| j.manager.clone())
            .collect()
    }

    /// Create and wire up the manager for an admitted job.  Runs under
    /// the table lock (manager locks nest inside it).
    fn start_job_locked(&self, ts: &mut TableState, id: u64) -> Result<()> {
        let members: Vec<(WorkerId, u64)> =
            ts.members.iter().map(|(&w, &lease)| (w, lease)).collect();
        let Some(job) = ts.jobs.get_mut(&id) else {
            return Ok(());
        };
        let mgr = Manager::new_staged(job.workflow.clone(), self.n_chunks, self.policy.clone())?;
        if self.journal.load(Ordering::Acquire) {
            mgr.enable_journal();
        }
        for (w, lease) in members {
            mgr.register_worker(w, lease);
        }
        if let Some((journal, catalog)) = job.pending_restore.take() {
            mgr.restore_from(journal, catalog)?;
        }
        job.manager = Some(mgr);
        job.state = JobState::Running;
        Ok(())
    }

    /// Advance the lifecycle: retire running jobs whose manager finished
    /// or failed, then promote queued jobs into free run slots.
    /// Announcements are collected under the lock and printed outside it.
    fn reap(&self) {
        let mut info: Vec<String> = Vec::new();
        let mut lines: Vec<String> = Vec::new();
        let mut changed = false;
        {
            let mut t = sync::lock_clean(&self.table);
            let ts = &mut *t;
            for job in ts.jobs.values_mut() {
                if job.state != JobState::Running {
                    continue;
                }
                let Some(mgr) = job.manager.clone() else {
                    continue;
                };
                if let Some(err) = mgr.error() {
                    let (d, tot) = mgr.progress();
                    job.done_hint = d as u64;
                    job.total_hint = tot as u64;
                    job.loc_hint = mgr.locality_stats();
                    job.error = Some(err.clone());
                    job.state =
                        if job.cancelled { JobState::Cancelled } else { JobState::Failed };
                    // free the in-flight state; nothing gets requeued
                    job.manager = None;
                    changed = true;
                    info.push(format!(
                        "job {} [{}] -> {} ({err})",
                        job.id,
                        job.tenant,
                        job.state.name()
                    ));
                } else if mgr.is_done() {
                    job.state = JobState::Done;
                    changed = true;
                    info.push(format!("job {} [{}] -> Done", job.id, job.tenant));
                    // reduce outputs, rendered exactly like a single-job
                    // run summary (prefixed so tenants' lines untangle)
                    for (si, stage) in job.workflow.stages.iter().enumerate() {
                        if stage.kind != StageKind::Reduce {
                            continue;
                        }
                        let _ = si;
                        if let Some(outs) = mgr.reduce_outputs(&stage.name) {
                            for (i, v) in outs.iter().enumerate() {
                                lines.push(format!(
                                    "job {} [{}] reduce '{}' [{}] = {}",
                                    job.id,
                                    job.tenant,
                                    stage.name,
                                    i,
                                    render_value(v)
                                ));
                            }
                        }
                    }
                }
            }
            // promotion: fill free run slots in job-id (submission) order
            let mut running =
                ts.jobs.values().filter(|j| j.state == JobState::Running).count();
            let queued: Vec<u64> = ts
                .jobs
                .values()
                .filter(|j| j.state == JobState::Queued)
                .map(|j| j.id)
                .collect();
            for id in queued {
                if running >= self.max_jobs {
                    break;
                }
                match self.start_job_locked(ts, id) {
                    Ok(()) => {
                        running += 1;
                        changed = true;
                        if let Some(job) = ts.jobs.get(&id) {
                            info.push(format!(
                                "job {} [{}] -> Running ('{}', {} instances)",
                                job.id,
                                job.tenant,
                                job.workflow.name,
                                job.total_hint
                            ));
                        }
                    }
                    Err(e) => {
                        changed = true;
                        if let Some(job) = ts.jobs.get_mut(&id) {
                            job.error = Some(e.to_string());
                            job.state = JobState::Failed;
                            info.push(format!(
                                "job {} [{}] -> Failed ({e})",
                                job.id, job.tenant
                            ));
                        }
                    }
                }
            }
        }
        if self.announce.load(Ordering::Acquire) {
            for l in &info {
                eprintln!("htap serve: {l}");
            }
            for l in &lines {
                println!("{l}");
            }
        }
        if changed {
            self.cv.notify_all();
        }
    }

    /// The deficit-round-robin sweep behind [`Endpoint::request_work`]:
    /// each active tenant earns `weight` credit per round and spends it
    /// one assignment at a time across its running jobs (id order), until
    /// the request's capacity is filled or nothing more is assignable.
    /// A tenant with nothing assignable forfeits its accumulated credit
    /// (the classic DRR empty-queue rule), so idle tenants cannot hoard
    /// bursts.
    fn poll_assign(&self, req: &WorkRequest) -> WorkBatch {
        let mut t = sync::lock_clean(&self.table);
        let ts = &mut *t;
        if ts.stop {
            // non-idle empty batch: the worker shuts down
            return WorkBatch::default();
        }
        let mut out = WorkBatch::default();
        let tenants: Vec<String> = ts.tenants.keys().cloned().collect();
        let mut remaining = req.capacity.max(1);
        if !tenants.is_empty() {
            let n = tenants.len();
            let start = ts.rr_cursor % n;
            ts.rr_cursor = ts.rr_cursor.wrapping_add(1);
            loop {
                let mut granted_this_round = 0usize;
                for k in 0..n {
                    if remaining == 0 {
                        break;
                    }
                    let name = &tenants[(start + k) % n];
                    let quantum = {
                        let Some(share) = ts.tenants.get_mut(name) else { continue };
                        share.deficit += u64::from(share.weight.max(1));
                        (share.deficit).min(remaining as u64) as usize
                    };
                    let mut got = 0usize;
                    for job in ts.jobs.values_mut() {
                        if got >= quantum {
                            break;
                        }
                        if job.state != JobState::Running || job.tenant != *name {
                            continue;
                        }
                        let Some(mgr) = job.manager.clone() else { continue };
                        // deltas were broadcast via observe_worker before
                        // this sweep; the sub-request carries identity only
                        let sub = WorkRequest {
                            capacity: quantum - got,
                            worker: req.worker,
                            prefetch_budget: req.prefetch_budget,
                            ..Default::default()
                        };
                        let batch = mgr.try_request_work(&sub);
                        if batch.assignments.is_empty() {
                            continue;
                        }
                        got += batch.assignments.len();
                        job.assigned += batch.assignments.len() as u64;
                        for mut a in batch.assignments {
                            a.instance_id = tag_instance(job.id, a.instance_id);
                            out.assignments.push(a);
                        }
                        for c in batch.prefetch {
                            if !out.prefetch.contains(&c) {
                                out.prefetch.push(c);
                            }
                        }
                        for c in batch.replicate {
                            if !out.replicate.contains(&c) {
                                out.replicate.push(c);
                            }
                        }
                    }
                    if let Some(share) = ts.tenants.get_mut(name) {
                        if got == 0 {
                            share.deficit = 0;
                        } else {
                            share.deficit = share.deficit.saturating_sub(got as u64);
                            share.assigned += got as u64;
                        }
                    }
                    remaining = remaining.saturating_sub(got);
                    granted_this_round += got;
                }
                if remaining == 0 || granted_this_round == 0 {
                    break;
                }
            }
        }
        // the service never ends by exhaustion — an empty batch means
        // "poll again", not "shut down"
        out.idle = out.assignments.is_empty();
        out
    }

    /// Snapshot every job for a service checkpoint.  Table metadata is
    /// captured under the table lock; each running manager's journal +
    /// catalog snapshot takes that manager's lock afterwards (table →
    /// manager order, no overlap).
    pub fn snapshot(&self) -> Vec<JobCheckpoint> {
        struct Meta {
            job: u64,
            tenant: String,
            priority: u32,
            state: String,
            workflow_json: String,
            done: u64,
            total: u64,
            manager: Option<Arc<Manager>>,
        }
        let metas: Vec<Meta> = {
            let t = sync::lock_clean(&self.table);
            t.jobs
                .values()
                .map(|j| {
                    let (done, total) = match &j.manager {
                        Some(m) => {
                            let (d, tt) = m.progress();
                            (d as u64, tt as u64)
                        }
                        None => (j.done_hint, j.total_hint),
                    };
                    Meta {
                        job: j.id,
                        tenant: j.tenant.clone(),
                        priority: j.priority,
                        state: j.state.name().to_string(),
                        workflow_json: j.workflow_json.clone(),
                        done,
                        total,
                        manager: if j.state == JobState::Running {
                            j.manager.clone()
                        } else {
                            None
                        },
                    }
                })
                .collect()
        };
        metas
            .into_iter()
            .map(|m| {
                let (journal, catalog) = match &m.manager {
                    Some(mgr) => mgr.checkpoint_state(),
                    None => (Vec::new(), Vec::new()),
                };
                JobCheckpoint {
                    job: m.job,
                    tenant: m.tenant,
                    priority: m.priority,
                    state: m.state,
                    workflow_json: m.workflow_json,
                    done: m.done,
                    total: m.total,
                    journal,
                    catalog,
                }
            })
            .collect()
    }

    /// Rebuild the table from a service checkpoint (`htap serve
    /// --resume`).  Non-terminal jobs come back `Queued` with their
    /// journal + catalog pending; admission replays them (in job-id
    /// order) into free run slots, where the restore happens against a
    /// fresh manager.  Terminal jobs come back manager-less with their
    /// snapshot progress.  Returns how many non-terminal jobs resumed.
    pub fn restore(&self, jobs: Vec<JobCheckpoint>) -> Result<usize> {
        let mut resumed = 0usize;
        // compile workflows outside the table lock
        let mut prepared = Vec::with_capacity(jobs.len());
        for jc in jobs {
            let state = JobState::parse(&jc.state).ok_or_else(|| {
                Error::Config(format!("service checkpoint: unknown job state '{}'", jc.state))
            })?;
            let wf = Arc::new(workflow_from_str(&jc.workflow_json, self.registry.clone())?);
            prepared.push((jc, state, wf));
        }
        {
            let mut t = sync::lock_clean(&self.table);
            let ts = &mut *t;
            for (jc, state, wf) in prepared {
                if jc.job == 0 || jc.job >= MAX_JOB_ID {
                    return Err(Error::Config(format!(
                        "service checkpoint: job id {} out of range",
                        jc.job
                    )));
                }
                if ts.jobs.contains_key(&jc.job) {
                    return Err(Error::Config(format!(
                        "service checkpoint: duplicate job id {}",
                        jc.job
                    )));
                }
                ts.next_job = ts.next_job.max(jc.job + 1);
                let share = ts
                    .tenants
                    .entry(jc.tenant.clone())
                    .or_insert(TenantShare { weight: 1, deficit: 0, assigned: 0 });
                share.weight = jc.priority.max(1);
                let terminal = state.terminal();
                let total = if jc.total > 0 {
                    jc.total
                } else {
                    total_instances(&wf, self.n_chunks)
                };
                ts.jobs.insert(
                    jc.job,
                    Job {
                        id: jc.job,
                        tenant: jc.tenant,
                        priority: jc.priority,
                        workflow_json: jc.workflow_json,
                        workflow: wf,
                        state: if terminal { state } else { JobState::Queued },
                        manager: None,
                        assigned: 0,
                        cancelled: state == JobState::Cancelled,
                        error: None,
                        pending_restore: if terminal {
                            None
                        } else {
                            Some((jc.journal, jc.catalog))
                        },
                        done_hint: jc.done,
                        total_hint: total,
                        loc_hint: (0, 0, 0),
                    },
                );
                if !terminal {
                    resumed += 1;
                }
            }
        }
        self.reap();
        Ok(resumed)
    }
}

impl Endpoint for JobTable {
    fn request_work(&self, req: &WorkRequest) -> WorkBatch {
        // lifecycle first, so a job finished by the previous completion
        // frees its run slot before this sweep
        self.reap();
        // broadcast the (consumed-once) staging deltas and the liveness
        // signal to *every* running job's catalog — the DRR sweep only
        // asks some managers for work, but all of them track this worker
        for mgr in self.running_managers() {
            mgr.observe_worker(req);
        }
        self.poll_assign(req)
    }

    fn complete(&self, instance: u64, outputs: Vec<Value>) {
        let mgr = {
            let t = sync::lock_clean(&self.table);
            t.jobs.get(&job_of(instance)).and_then(|j| j.manager.clone())
        };
        if let Some(m) = mgr {
            m.complete_instance(local_of(instance), outputs);
        }
        // else: completion for a cancelled/failed job — drop it
        self.reap();
    }

    fn fail(&self, msg: String) {
        // a worker-fatal error poisons every running job: the workers
        // share one runtime, so no job's results can be trusted past it
        for mgr in self.running_managers() {
            mgr.fail(msg.clone());
        }
        self.reap();
    }

    fn register_worker(&self, worker: WorkerId, lease_ms: u64) {
        {
            let mut t = sync::lock_clean(&self.table);
            t.members.insert(worker, lease_ms);
        }
        for mgr in self.running_managers() {
            mgr.register_worker(worker, lease_ms);
        }
    }

    fn heartbeat_worker(&self, worker: WorkerId) {
        for mgr in self.running_managers() {
            mgr.heartbeat_worker(worker);
        }
    }

    fn expire_worker(&self, worker: WorkerId) -> usize {
        {
            let mut t = sync::lock_clean(&self.table);
            t.members.remove(&worker);
        }
        let mut requeued = 0;
        for mgr in self.running_managers() {
            requeued += mgr.expire_worker(worker);
        }
        self.reap();
        requeued
    }

    fn purge_worker(&self, worker: WorkerId) -> usize {
        let mut purged = 0;
        for mgr in self.running_managers() {
            purged += mgr.purge_worker(worker);
        }
        purged
    }

    fn requeue_stale(&self, leases: &[u64]) -> usize {
        // group tagged leases by owning job, requeue per manager
        let mut by_job: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
        for &gid in leases {
            by_job.entry(job_of(gid)).or_default().push(local_of(gid));
        }
        let mut requeued = 0;
        for (job, locals) in by_job {
            let mgr = {
                let t = sync::lock_clean(&self.table);
                t.jobs.get(&job).and_then(|j| j.manager.clone())
            };
            if let Some(m) = mgr {
                requeued += m.requeue_stale(&locals);
            }
        }
        requeued
    }

    fn sweep_leases(&self) -> Vec<(WorkerId, usize)> {
        let mut merged: BTreeMap<WorkerId, usize> = BTreeMap::new();
        for mgr in self.running_managers() {
            for (w, n) in mgr.sweep_leases() {
                *merged.entry(w).or_insert(0) += n;
            }
        }
        if !merged.is_empty() {
            let mut t = sync::lock_clean(&self.table);
            for w in merged.keys() {
                t.members.remove(w);
            }
        }
        merged.into_iter().collect()
    }

    fn wait_done(&self) {
        let mut t = sync::lock_clean(&self.table);
        while !t.stop {
            t = match self.cv.wait(t) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    fn submit(&self, tenant: &str, workflow_json: &str, priority: u32) -> Result<u64> {
        if tenant.is_empty() {
            return Err(Error::Scheduler("submit: tenant name must not be empty".into()));
        }
        // compile + validate outside the table lock: a malformed
        // submission is rejected before it touches any shared state
        let wf = Arc::new(workflow_from_str(workflow_json, self.registry.clone())?);
        let total = total_instances(&wf, self.n_chunks);
        let id = {
            let mut t = sync::lock_clean(&self.table);
            let ts = &mut *t;
            if ts.stop {
                return Err(Error::Scheduler("submit: service is shutting down".into()));
            }
            let depth = ts
                .jobs
                .values()
                .filter(|j| j.tenant == tenant && !j.state.terminal())
                .count();
            if depth >= self.tenant_queue_depth {
                return Err(Error::Scheduler(format!(
                    "submit: tenant '{tenant}' already has {depth} queued/running jobs \
                     (limit {})",
                    self.tenant_queue_depth
                )));
            }
            let id = ts.next_job;
            if id >= MAX_JOB_ID {
                return Err(Error::Scheduler("submit: job id space exhausted".into()));
            }
            ts.next_job += 1;
            let share = ts
                .tenants
                .entry(tenant.to_string())
                .or_insert(TenantShare { weight: 1, deficit: 0, assigned: 0 });
            // the latest submission sets the tenant's fair-share weight
            share.weight = priority.max(1);
            ts.jobs.insert(
                id,
                Job {
                    id,
                    tenant: tenant.to_string(),
                    priority,
                    workflow_json: workflow_json.to_string(),
                    workflow: wf,
                    state: JobState::Queued,
                    manager: None,
                    assigned: 0,
                    cancelled: false,
                    error: None,
                    pending_restore: None,
                    done_hint: 0,
                    total_hint: total,
                    loc_hint: (0, 0, 0),
                },
            );
            id
        };
        // admission may promote it straight into a free run slot
        self.reap();
        Ok(id)
    }

    fn cancel_job(&self, job: u64) -> Result<()> {
        let mgr = {
            let mut t = sync::lock_clean(&self.table);
            let Some(j) = t.jobs.get_mut(&job) else {
                return Err(Error::Scheduler(format!("cancel: no job {job}")));
            };
            match j.state {
                JobState::Queued => {
                    j.cancelled = true;
                    j.state = JobState::Cancelled;
                    None
                }
                JobState::Running => {
                    j.cancelled = true;
                    j.manager.clone()
                }
                s => {
                    return Err(Error::Scheduler(format!(
                        "cancel: job {job} is already {}",
                        s.name()
                    )))
                }
            }
        };
        if let Some(m) = mgr {
            // failing the manager unblocks everything waiting on it; the
            // reap maps the error to Cancelled (cancelled flag is set) and
            // drops the manager — in-flight leases die with it, nothing
            // is requeued, and late completions are dropped in complete()
            m.fail(format!("job {job} cancelled by operator"));
        }
        self.reap();
        Ok(())
    }

    fn job_report(&self, job: u64) -> Vec<JobSummary> {
        self.reap();
        let mut rows: Vec<JobSummary> = {
            let t = sync::lock_clean(&self.table);
            t.jobs
                .values()
                .filter(|j| job == 0 || j.id == job)
                .map(Job::summary)
                .collect()
        };
        // join the per-job trace rollups in (collector lock only, after
        // the table lock is released)
        let rollups = self.collector.job_rollups();
        for row in &mut rows {
            if let Some(r) = rollups.iter().find(|r| r.job == row.job) {
                row.ops = r.ops;
                row.busy_us = r.busy_us;
            }
        }
        rows
    }

    fn job_spec(&self, job: u64) -> Result<(String, String)> {
        let t = sync::lock_clean(&self.table);
        match t.jobs.get(&job) {
            Some(j) => Ok((j.tenant.clone(), j.workflow_json.clone())),
            None => Err(Error::Scheduler(format!("job spec: no job {job}"))),
        }
    }

    fn trace_batch(&self, worker: WorkerId, events: Vec<TraceEvent>) {
        self.collector.ingest(worker, events);
    }

    fn utilization(&self) -> Vec<UtilRow> {
        let mut rows = self.collector.util_rows();
        // tenant attribution: the collector only knows job ids
        let t = sync::lock_clean(&self.table);
        for row in &mut rows {
            if let Some(j) = t.jobs.get(&row.job) {
                row.tenant.clone_from(&j.tenant);
            }
        }
        rows
    }
}

/// In-process test/driver convenience: a [`JobTable`] is a [`WorkSource`]
/// too, so the threaded worker harness can drive it directly.
impl WorkSource for JobTable {
    fn request_work(&self, req: &WorkRequest) -> WorkBatch {
        Endpoint::request_work(self, req)
    }

    fn complete(&self, instance_id: u64, outputs: Vec<Value>) {
        Endpoint::complete(self, instance_id, outputs)
    }

    fn register(&self, worker: WorkerId, lease_ms: u64) {
        Endpoint::register_worker(self, worker, lease_ms)
    }

    fn heartbeat(&self, worker: WorkerId) {
        Endpoint::heartbeat_worker(self, worker)
    }

    fn goodbye(&self, worker: WorkerId) {
        Endpoint::expire_worker(self, worker);
    }

    fn trace_events(&self, worker: WorkerId, events: Vec<TraceEvent>) {
        Endpoint::trace_batch(self, worker, events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> Arc<OpRegistry> {
        let mut r = OpRegistry::new();
        r.register_cpu("double", 1, |args: &[Value]| {
            Ok(vec![Value::Scalar(args[0].as_scalar()? * 2.0)])
        })
        .unwrap();
        r.register_cpu("sum", 1, |args: &[Value]| {
            let mut s = 0.0;
            for a in args {
                s += a.as_scalar()?;
            }
            Ok(vec![Value::Scalar(s)])
        })
        .unwrap();
        Arc::new(r)
    }

    const DOUBLE_SUM: &str = r#"{
        "name": "double-sum",
        "stages": [
            {
                "name": "double", "kind": "per_chunk", "inputs": ["chunk"],
                "ops": [ { "op": "double", "inputs": [ {"input": 0} ] } ],
                "outputs": [ {"op": "double"} ]
            },
            {
                "name": "total", "kind": "reduce",
                "inputs": [ {"stage": "double", "output": 0} ],
                "ops": [ { "op": "sum", "inputs": "all" } ],
                "outputs": [ {"op": "sum"} ]
            }
        ]
    }"#;

    fn table(max_jobs: usize, depth: usize) -> Arc<JobTable> {
        JobTable::new(reg(), 4, AssignPolicy::default(), max_jobs, depth)
    }

    /// Drive the table to completion as one synthetic worker: chunk
    /// payloads are `Scalar(chunk)`, per-chunk stage doubles, reduce
    /// sums the shipped upstream inputs.
    fn drive(table: &JobTable, worker: WorkerId) -> usize {
        let mut executed = 0;
        loop {
            let req = WorkRequest { capacity: 3, worker, ..Default::default() };
            let batch = Endpoint::request_work(table, &req);
            if batch.assignments.is_empty() {
                if batch.idle {
                    // nothing assignable right now: are we actually done?
                    let open = Endpoint::job_report(table, 0)
                        .iter()
                        .filter(|s| !matches!(s.state.as_str(), "Done" | "Failed" | "Cancelled"))
                        .count();
                    if open == 0 {
                        return executed;
                    }
                    std::thread::yield_now();
                    continue;
                }
                return executed; // stop: shut down
            }
            for a in batch.assignments {
                let out = if a.needs_chunk {
                    // per-chunk stage: payload is Scalar(chunk), doubled
                    Value::Scalar(a.chunk as f32 * 2.0)
                } else {
                    // reduce stage: upstream values ship in the inputs
                    let mut s = 0.0;
                    for v in &a.inputs {
                        s += v.as_scalar().unwrap();
                    }
                    Value::Scalar(s)
                };
                Endpoint::complete(table, a.instance_id, vec![out]);
                executed += 1;
            }
        }
    }

    #[test]
    fn instance_tagging_roundtrips() {
        for &(job, local) in
            &[(0u64, 0u64), (1, 0), (1, 1), (42, 12345), (MAX_JOB_ID - 1, LOCAL_MASK)]
        {
            let gid = tag_instance(job, local);
            assert_eq!(job_of(gid), job);
            assert_eq!(local_of(gid), local);
        }
    }

    #[test]
    fn job_state_names_roundtrip() {
        for s in [
            JobState::Queued,
            JobState::Running,
            JobState::Done,
            JobState::Failed,
            JobState::Cancelled,
        ] {
            assert_eq!(JobState::parse(s.name()), Some(s));
        }
        assert_eq!(JobState::parse("Zombie"), None);
        assert!(JobState::Done.terminal() && !JobState::Running.terminal());
    }

    #[test]
    fn submit_runs_to_done_with_correct_reduce() {
        let t = table(4, 8);
        let job = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
        assert_eq!(job, 1);
        let report = Endpoint::job_report(&*t, job);
        assert_eq!(report.len(), 1);
        assert_eq!(report[0].state, "Running"); // promoted immediately
        assert_eq!(report[0].total, 5); // 4 per-chunk + 1 reduce
        drive(&t, 7);
        let report = Endpoint::job_report(&*t, job);
        assert_eq!(report[0].state, "Done");
        assert_eq!(report[0].done, 5);
        // chunks 0..4 doubled and summed: 2*(0+1+2+3) = 12
        let outs = t.reduce_outputs(job, "total").unwrap();
        assert_eq!(outs, vec![Value::Scalar(12.0)]);
    }

    #[test]
    fn admission_queues_beyond_max_jobs_and_rejects_beyond_depth() {
        let t = table(1, 2);
        let a = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
        let b = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
        // depth limit: two non-terminal jobs already
        assert!(Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).is_err());
        // another tenant is unaffected
        let c = Endpoint::submit(&*t, "bob", DOUBLE_SUM, 1).unwrap();
        let states: Vec<(u64, String)> = Endpoint::job_report(&*t, 0)
            .into_iter()
            .map(|s| (s.job, s.state))
            .collect();
        assert_eq!(
            states,
            vec![
                (a, "Running".to_string()),
                (b, "Queued".to_string()),
                (c, "Queued".to_string())
            ]
        );
        drive(&t, 7);
        for s in Endpoint::job_report(&*t, 0) {
            assert_eq!(s.state, "Done", "job {} should finish", s.job);
        }
    }

    #[test]
    fn malformed_submission_is_rejected_cleanly() {
        let t = table(4, 8);
        assert!(Endpoint::submit(&*t, "alice", "{ not json", 1).is_err());
        assert!(Endpoint::submit(&*t, "", DOUBLE_SUM, 1).is_err());
        let doc = r#"{"name":"bad","stages":[{"name":"s","kind":"per_chunk",
            "inputs":["chunk"],"ops":[{"op":"ghost","inputs":[{"input":0}]}],
            "outputs":[{"op":"ghost"}]}]}"#;
        assert!(Endpoint::submit(&*t, "alice", doc, 1).is_err());
        assert!(Endpoint::job_report(&*t, 0).is_empty());
    }

    #[test]
    fn cancel_queued_and_running_jobs() {
        let t = table(1, 8);
        let a = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
        let b = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
        // b is queued; cancelling it never starts it
        Endpoint::cancel_job(&*t, b).unwrap();
        // a is running; cancel mid-run
        Endpoint::cancel_job(&*t, a).unwrap();
        let report = Endpoint::job_report(&*t, 0);
        assert_eq!(report[0].state, "Cancelled");
        assert_eq!(report[1].state, "Cancelled");
        // cancelling again is an error, not a panic
        assert!(Endpoint::cancel_job(&*t, a).is_err());
        assert!(Endpoint::cancel_job(&*t, 99).is_err());
        // the queue depth was freed: a new submission is admitted and runs
        let c = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
        drive(&t, 7);
        assert_eq!(Endpoint::job_report(&*t, c)[0].state, "Done");
    }

    #[test]
    fn shutdown_sends_workers_home() {
        let t = table(4, 8);
        t.shutdown();
        let batch =
            Endpoint::request_work(&*t, &WorkRequest { capacity: 2, ..Default::default() });
        assert!(batch.assignments.is_empty() && !batch.idle);
        assert!(Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).is_err());
        Endpoint::wait_done(&*t); // returns immediately after shutdown
    }

    #[test]
    fn single_job_manager_rejects_service_calls() {
        let wf = Arc::new(
            workflow_from_str(DOUBLE_SUM, reg()).unwrap(),
        );
        let mgr = Manager::new_staged(wf, 2, AssignPolicy::default()).unwrap();
        assert!(Endpoint::submit(&*mgr, "alice", DOUBLE_SUM, 1).is_err());
        assert!(Endpoint::cancel_job(&*mgr, 1).is_err());
        assert!(Endpoint::job_spec(&*mgr, 1).is_err());
        assert!(Endpoint::job_report(&*mgr, 0).is_empty());
    }

    #[test]
    fn job_spec_serves_the_submitted_json() {
        let t = table(4, 8);
        let job = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 3).unwrap();
        let (tenant, json) = Endpoint::job_spec(&*t, job).unwrap();
        assert_eq!(tenant, "alice");
        assert_eq!(json, DOUBLE_SUM);
        assert!(Endpoint::job_spec(&*t, 99).is_err());
    }

    #[test]
    fn snapshot_restore_roundtrip_resumes_progress() {
        let t = table(4, 8);
        t.enable_journal();
        let job = Endpoint::submit(&*t, "alice", DOUBLE_SUM, 2).unwrap();
        // complete two per-chunk instances, then snapshot
        let req = WorkRequest { capacity: 2, worker: 7, ..Default::default() };
        let batch = Endpoint::request_work(&*t, &req);
        assert_eq!(batch.assignments.len(), 2);
        for a in batch.assignments {
            Endpoint::complete(&*t, a.instance_id, vec![Value::Scalar(a.chunk as f32 * 2.0)]);
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].journal.len(), 2);

        let t2 = table(4, 8);
        t2.enable_journal();
        assert_eq!(t2.restore(snap).unwrap(), 1);
        let report = Endpoint::job_report(&*t2, job);
        assert_eq!(report[0].state, "Running");
        assert_eq!(report[0].done, 2, "replayed completions count as progress");
        drive(&t2, 7);
        let outs = t2.reduce_outputs(job, "total").unwrap();
        assert_eq!(outs, vec![Value::Scalar(12.0)], "resumed run is bit-identical");
    }

    #[test]
    fn restore_keeps_terminal_jobs_without_managers() {
        let t = table(4, 8);
        let job = Endpoint::submit(&*t, "bob", DOUBLE_SUM, 1).unwrap();
        drive(&t, 7);
        let snap = t.snapshot();
        assert_eq!(snap[0].state, "Done");
        let t2 = table(4, 8);
        assert_eq!(t2.restore(snap).unwrap(), 0, "terminal jobs are not resumed");
        let report = Endpoint::job_report(&*t2, job);
        assert_eq!(report[0].state, "Done");
        assert_eq!(report[0].done, 5);
        assert_eq!(report[0].total, 5);
    }

    #[test]
    fn deficit_round_robin_respects_weights() {
        // two tenants, weights 1:4, both with deep backlogs on a big
        // chunk set; a capacity-10 sweep should split ~2:8
        let t = JobTable::new(reg(), 50, AssignPolicy::default(), 4, 8);
        Endpoint::submit(&*t, "alice", DOUBLE_SUM, 1).unwrap();
        Endpoint::submit(&*t, "bob", DOUBLE_SUM, 4).unwrap();
        let req = WorkRequest { capacity: 10, worker: 7, ..Default::default() };
        let batch = Endpoint::request_work(&*t, &req);
        assert_eq!(batch.assignments.len(), 10);
        let shares = t.tenant_assignments();
        let alice = shares.iter().find(|(n, _, _)| n == "alice").unwrap().2;
        let bob = shares.iter().find(|(n, _, _)| n == "bob").unwrap().2;
        assert_eq!(alice + bob, 10);
        assert_eq!(alice, 2, "weight-1 tenant gets 2 of 10");
        assert_eq!(bob, 8, "weight-4 tenant gets 8 of 10");
    }

    #[test]
    fn drained_tenant_forfeits_deficit_and_others_fill_capacity() {
        // alice has a tiny job (2 instances assignable: 2 chunks), bob a
        // bigger one; alice's queue drains mid-sweep and bob takes the rest
        let t = JobTable::new(reg(), 6, AssignPolicy::default(), 4, 8);
        const TINY: &str = r#"{
            "name": "tiny",
            "stages": [
                { "name": "double", "kind": "per_chunk", "inputs": ["chunk"],
                  "ops": [ { "op": "double", "inputs": [ {"input": 0} ] } ],
                  "outputs": [ {"op": "double"} ] }
            ]
        }"#;
        let _ = TINY;
        Endpoint::submit(&*t, "alice", DOUBLE_SUM, 5).unwrap();
        Endpoint::submit(&*t, "bob", DOUBLE_SUM, 1).unwrap();
        // both per-chunk backlogs are 6; alice (weight 5) may take at most
        // 6 before draining, bob fills the remaining capacity regardless
        // of his weight-1 trickle
        let req = WorkRequest { capacity: 12, worker: 7, ..Default::default() };
        let batch = Endpoint::request_work(&*t, &req);
        assert_eq!(batch.assignments.len(), 12, "capacity fills even past one tenant");
        let mut by_job: BTreeMap<u64, usize> = BTreeMap::new();
        for a in &batch.assignments {
            *by_job.entry(job_of(a.instance_id)).or_insert(0) += 1;
        }
        assert_eq!(by_job.get(&1), Some(&6), "alice drained her backlog");
        assert_eq!(by_job.get(&2), Some(&6), "bob filled the rest");
    }

    #[test]
    fn render_value_matches_run_summary_format() {
        assert_eq!(render_value(&Value::Scalar(12.0)), "12");
        let t = crate::runtime::HostTensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let s = render_value(&Value::Tensor(t));
        assert!(s.starts_with("tensor[2]#"), "{s}");
    }
}
