//! Hand-rolled CLI parsing (clap is not in the offline crate set).
//!
//! `htap <command> [--key value ...]`; commands map to the launcher modes
//! in `main.rs`: `run`, `sim`, `calibrate`, `manager`, `worker`.  A flag
//! followed by another flag (or nothing) is boolean: `--quick` parses as
//! `--quick true`.

use crate::config::RunConfig;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Flags that take no value.  Everything else still requires one, so a
/// forgotten value for a string/path flag is an error, not a silent
/// `"true"`.
const BOOL_FLAGS: &[&str] = &[
    "quick",
    "no-dl",
    "no-prefetch",
    "no-locality",
    "no-replication",
    "resume",
    "warm-restart",
    "standby",
];

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Cli {
    pub command: String,
    pub flags: BTreeMap<String, String>,
}

impl Cli {
    /// Parse `args` (excluding argv[0]).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut it = args.iter();
        let command = it
            .next()
            .cloned()
            .ok_or_else(|| Error::Config(USAGE.trim().to_string()))?;
        let mut flags = BTreeMap::new();
        while let Some(arg) = it.next() {
            let key = arg
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --flag, got '{arg}'")))?;
            let val = if BOOL_FLAGS.contains(&key) {
                // boolean flags need no value; an explicit true/false is
                // accepted (`--quick` == `--quick true`)
                match it.clone().next() {
                    Some(v) if matches!(v.as_str(), "true" | "false") => {
                        it.next().cloned().unwrap()
                    }
                    _ => "true".to_string(),
                }
            } else {
                // every other flag still requires a value, so a forgotten
                // one (`--out` with nothing after) stays a hard error
                // instead of silently becoming the string "true"
                it.next()
                    .cloned()
                    .ok_or_else(|| Error::Config(format!("flag --{key} needs a value")))?
            };
            flags.insert(key.to_string(), val);
        }
        Ok(Cli { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// A boolean flag: present with value "true" (bare `--flag` counts).
    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} must be a number, got '{v}'"))),
        }
    }

    /// Build a [`RunConfig`] from `--config file.json` plus flag overrides.
    pub fn run_config(&self) -> Result<RunConfig> {
        let mut cfg = match self.get("config") {
            Some(path) => RunConfig::from_file(path)?,
            None => RunConfig::default(),
        };
        if let Some(v) = self.get("tile-size") {
            cfg.tile_size = v.parse().map_err(|_| Error::Config("bad --tile-size".into()))?;
        }
        if let Some(v) = self.get("tiles") {
            cfg.n_tiles = v.parse().map_err(|_| Error::Config("bad --tiles".into()))?;
        }
        if let Some(v) = self.get("cpus") {
            cfg.cpu_workers = v.parse().map_err(|_| Error::Config("bad --cpus".into()))?;
        }
        if let Some(v) = self.get("gpus") {
            cfg.gpu_workers = v.parse().map_err(|_| Error::Config("bad --gpus".into()))?;
        }
        if let Some(v) = self.get("window") {
            cfg.window = v.parse().map_err(|_| Error::Config("bad --window".into()))?;
        }
        if let Some(v) = self.get("policy") {
            cfg.policy = crate::config::Policy::parse(v)?;
        }
        if let Some(v) = self.get("placement") {
            cfg.placement = crate::config::Placement::parse(v)?;
        }
        if let Some(v) = self.get("no-dl") {
            cfg.data_locality = v != "true";
        }
        if let Some(v) = self.get("no-prefetch") {
            cfg.prefetch = v != "true";
        }
        if let Some(v) = self.get("no-locality") {
            cfg.chunk_locality = v != "true";
        }
        if let Some(v) = self.get("staging-cap") {
            // N = chunks (back-compat), NMB/NKB/NGB = byte budget
            cfg.staging_cap = crate::config::CacheCap::parse(v)?;
        }
        if let Some(v) = self.get("prefetch-depth") {
            cfg.prefetch_depth =
                v.parse().map_err(|_| Error::Config("bad --prefetch-depth".into()))?;
        }
        if let Some(v) = self.get("spill-dir") {
            cfg.spill_dir = Some(v.to_string());
        }
        if let Some(v) = self.get("spill-cap") {
            cfg.spill_cap = crate::config::CacheCap::parse(v)?;
        }
        if let Some(v) = self.get("no-replication") {
            cfg.replication = v != "true";
        }
        if let Some(v) = self.get("partition") {
            cfg.partition = crate::config::PartitionMode::parse(v)?;
        }
        if let Some(v) = self.get("read-latency-ms") {
            cfg.read_latency_ms =
                v.parse().map_err(|_| Error::Config("bad --read-latency-ms".into()))?;
        }
        if let Some(v) = self.get("heartbeat-ms") {
            cfg.heartbeat_ms =
                v.parse().map_err(|_| Error::Config("bad --heartbeat-ms".into()))?;
        }
        if let Some(v) = self.get("lease-ms") {
            cfg.lease_ms = v.parse().map_err(|_| Error::Config("bad --lease-ms".into()))?;
        }
        if let Some(v) = self.get("max-jobs") {
            cfg.max_jobs = v.parse().map_err(|_| Error::Config("bad --max-jobs".into()))?;
        }
        if let Some(v) = self.get("tenant-queue-depth") {
            cfg.tenant_queue_depth =
                v.parse().map_err(|_| Error::Config("bad --tenant-queue-depth".into()))?;
        }
        if let Some(v) = self.get("tenant-quota") {
            cfg.tenant_quota = Some(crate::config::CacheCap::parse(v)?);
        }
        if let Some(v) = self.get("trace-out") {
            cfg.trace_out = Some(v.to_string());
        }
        if let Some(v) = self.get("fault-plan") {
            cfg.fault_plan = Some(v.to_string());
        }
        if let Some(v) = self.get("fault-seed") {
            cfg.fault_seed =
                v.parse().map_err(|_| Error::Config("bad --fault-seed".into()))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

pub const USAGE: &str = "
htap — high-throughput hierarchical analysis pipelines (Teodoro et al. 2012)

USAGE:
    htap run     [--tiles N] [--tile-size S] [--cpus N] [--gpus N]
                 [--policy fcfs|pats] [--window N] [--config file.json]
                 [--workflow wf.json] [--profiles profiles.json]
                 [--save-profiles out.json] [--chunk-source synth|dir:PATH]
                 [--staging-cap N|NMB] [--prefetch-depth N] [--no-locality]
                 [--spill-dir PATH] [--spill-cap N|NMB] [--read-latency-ms MS]
                 [--trace-out PATH] [--fault-plan SPEC] [--fault-seed N]
        run a workflow locally (default: the built-in WSI app; --workflow
        loads a declarative JSON workflow over the registered op set — see
        docs/workflow_api.md).  Chunks come from --chunk-source (synthetic
        tiles, or .tile files under a directory — see docs/staging.md) and
        stage through a bounded cache with async prefetch
        (--staging-cap/--prefetch-depth; --no-locality disables
        catalog-driven assignment; --read-latency-ms simulates shared-FS
        reads).  --spill-dir adds a bounded local-disk tier: evictions
        demote instead of dropping and misses promote from disk.  Both
        caps take a chunk count (N) or a byte budget (NMB, from tensor
        dims).  --profiles seeds PATS with measured
        estimates from `htap calibrate`; --save-profiles writes the
        post-run EWMA estimates out.  --trace-out records structured
        execution events (op spans, queue waits, staging activity) and
        writes a Chrome trace_event JSON (open in Perfetto) plus a .jsonl
        sidecar — see docs/observability.md.  --fault-plan arms seeded
        fault injection (`site=rate[@delay_ms][#max],...` — see
        docs/operations.md) and --fault-seed fixes where the faults land;
        the HTAP_FAULTS env var is a lower-precedence alternative

    htap sim     [--nodes N] [--tiles N] [--policy fcfs|pats]
                 [--profiles profiles.json] [--no-locality] [--no-replication]
                 [--kill-worker-at F] [--jobs N] [--job-weights W1,W2,...]
                 [--net-fault-rate F] [--fault-seed N] [--trace-out PATH]
        discrete-event simulation at cluster scale (Keeneland model);
        --profiles calibrates the cost model from measured estimates
        (including the chunk-read cost a calibrate --read-latency-ms run
        recorded); --no-locality makes repeat stages migrate across nodes
        and re-read their tiles (the Fig. 8-style locality-off control);
        --no-replication makes steal migrations pay cold re-reads instead
        of hinted prefetches (the tiered-storage control);
        --kill-worker-at F crashes the last node at fraction F (0..1) of
        the no-fault makespan and reports how many stage instances were
        re-executed on the survivors (the fault-injection mirror of the
        distributed lease-expiry path); --jobs N models N identical jobs
        sharing the cluster under weighted fair-share (--job-weights,
        default all 1) and prints each job's analytic makespan;
        --net-fault-rate F drops fraction F (0..1) of manager round-trips,
        each retried under the same bounded-backoff schedule real workers
        use (--fault-seed fixes which round-trips fail) and reports the
        retried-frame count; --trace-out writes the simulated schedule in
        the same Chrome trace_event schema real runs emit (virtual-time op
        spans per node)

    htap calibrate [--quick] [--tile-size S] [--tiles N] [--reps N]
                   [--seed N] [--read-latency-ms MS] [--out profiles.json]
        microbenchmark every registered op on synthetic tiles across the
        device kinds this host can execute, plus the per-chunk read cost
        under the simulated shared-FS latency, and write a versioned
        profiles.json consumed by run/sim/PATS (--quick: CI-sized pass)

    htap manager --listen HOST:PORT [--tiles N] [--tile-size S] [--workers N]
                 [--chunk-source synth|dir:PATH] [--workflow wf.json]
                 [--no-locality] [--no-replication] [--partition demand|init]
                 [--lease-ms MS] [--checkpoint-dir PATH] [--resume]
                 [--standby --primary HOST:PORT [--promote-after-ms MS]]
                 [--trace-out PATH] [--fault-plan SPEC] [--fault-seed N]
        serve stage instances to TCP workers.  Staged protocol: workers
        read chunk payloads from their own --chunk-source (tiles never
        cross the wire) and assignment is locality-aware via the chunk
        catalog unless --no-locality.  Steals replicate the chunk
        (multi-homed catalog + replicate hints) unless --no-replication;
        --partition init range-assigns cold chunks to worker ids
        1..=--workers up front (workers must pass matching --worker-id).
        Membership is elastic: workers may join, leave, and rejoin a
        running manager; a worker that misses its lease (--lease-ms,
        default 3000) is expired — its in-flight work re-issues to the
        survivors and its catalog entries purge.  --checkpoint-dir
        periodically snapshots manager progress (completion journal +
        chunk catalog); --resume restarts from that snapshot instead of
        from scratch after a manager crash.  --standby turns the process
        into a warm standby instead: it health-checks --primary, and when
        the primary stays silent for --promote-after-ms (default 3000) it
        restores the newest snapshot under --checkpoint-dir and starts
        serving on --listen — workers started with a multi-address
        --connect fail over to it through their retry policy.
        --trace-out merges the trace
        batches workers ship at heartbeat cadence with the manager's own
        membership events and writes the cluster-wide stream when the run
        completes

    htap serve   --listen HOST:PORT [--tiles N] [--tile-size S]
                 [--chunk-source synth|dir:PATH] [--max-jobs N]
                 [--tenant-queue-depth N] [--tenant-quota N|NMB]
                 [--no-locality] [--no-replication] [--lease-ms MS]
                 [--checkpoint-dir PATH] [--resume] [--run-for MS]
                 [--trace-out PATH]
        multi-tenant workflow service: a long-running manager that accepts
        wire submissions (`htap submit`) and runs many workflows
        concurrently over one shared elastic worker pool.  Tenants get
        weighted fair-share of worker capacity (deficit round-robin;
        weight = submission priority), --max-jobs bounds concurrently
        running jobs (the rest queue), --tenant-queue-depth bounds each
        tenant's queued-or-running jobs at admission, and --tenant-quota
        fences each tenant's share of every worker's staging cache.
        --checkpoint-dir snapshots the whole job table; --resume restores
        queued and in-flight jobs after a crash.  --run-for exits after MS
        milliseconds (tests); default runs until killed.  --trace-out
        writes the merged cluster-wide trace (every worker's shipped
        batches + membership events) when the service exits

    htap top     --connect HOST:PORT [--interval-ms MS] [--iterations N]
        live per-tenant / per-worker utilization of a running `htap serve`
        (or `htap manager`) daemon: ops completed and busy-µs from the
        manager's merged trace rollups, polled every --interval-ms
        (default 1000).  --iterations N stops after N polls (default 0 =
        until interrupted); --iterations 1 prints one table and exits

    htap submit  --connect HOST:PORT --workflow wf.json [--tenant NAME]
                 [--priority N]
        submit a JSON workflow to a running service; prints the job id and
        admission state (priority doubles as the tenant's fair-share
        weight; rejected submissions exit nonzero)

    htap jobs    --connect HOST:PORT [--job ID]
        list the service's jobs (or one job) with tenant, state, progress,
        locality counters, and priority

    htap cancel  --connect HOST:PORT --job ID
        cancel a queued or running job: queued jobs drop immediately;
        running jobs stop issuing new instances and release their tenant's
        cache claim

    htap worker  --connect HOST:PORT[,HOST:PORT...] [--cpus N] [--gpus N] [--window N]
                 [--chunk-source synth|dir:PATH] [--workflow wf.json]
                 [--worker-id N] [--staging-cap N|NMB] [--prefetch-depth N]
                 [--spill-dir PATH] [--spill-cap N|NMB] [--read-latency-ms MS]
                 [--heartbeat-ms MS] [--lease-ms MS] [--warm-restart]
                 [--tenant-quota N|NMB] [--drain-on file:PATH|signal[:term|int]]
                 [--trace-out PATH] [--fault-plan SPEC] [--fault-seed N]
        join a distributed run; --connect takes a comma-separated failover
        list (primary first, then standbys): a lost manager reconnects
        through bounded exponential backoff, rotating addresses until one
        answers, then re-identifies and re-advertises every staged and
        spilled chunk it still holds.  --chunk-source must serve the same dataset
        the manager was pointed at (same synth seed/tile count, or the
        same shared directory), and --workflow must load the same file the
        manager did.  The worker announces itself with a lease term
        (--lease-ms; 0 opts out of liveness tracking) and heartbeats every
        --heartbeat-ms.  --warm-restart recovers the surviving --spill-dir
        contents after a crash and re-advertises them to the manager as
        disk-tier chunks instead of clearing the directory.  Against
        `htap serve` the worker resolves each job's workflow over the wire
        (no --workflow needed) and fences tenants' cache shares with
        --tenant-quota.  --drain-on arms graceful drain: when the trigger
        fires (the file appears, or SIGTERM/SIGINT arrives) the worker
        finishes its in-flight instances, demotes its memory tier to the
        spill tier, sends Goodbye, and exits 0.  --trace-out arms
        structured tracing: op spans and staging events ship to the
        manager at heartbeat cadence (the manager's own --trace-out writes
        the merged cluster stream; `htap top` reads the live rollups);
        PATH only receives events a lost manager connection stranded
        locally

    htap export-tiles --dir PATH [--tiles N] [--tile-size S] [--seed N]
        write the synthetic dataset as .tile files for dir: chunk sources
";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|v| v.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let c = Cli::parse(&args(&["run", "--tiles", "32", "--policy", "fcfs"])).unwrap();
        assert_eq!(c.command, "run");
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.n_tiles, 32);
        assert_eq!(cfg.policy, crate::config::Policy::Fcfs);
    }

    #[test]
    fn boolean_flags_parse_without_values() {
        // trailing boolean flag
        let c = Cli::parse(&args(&["calibrate", "--quick"])).unwrap();
        assert!(c.get_flag("quick"));
        assert!(!c.get_flag("absent"));
        // boolean flag followed by another flag
        let c = Cli::parse(&args(&["run", "--no-dl", "--tiles", "4"])).unwrap();
        assert_eq!(c.get("no-dl"), Some("true"));
        assert_eq!(c.get("tiles"), Some("4"));
        // an explicit value still wins
        let c = Cli::parse(&args(&["calibrate", "--quick", "false"])).unwrap();
        assert!(!c.get_flag("quick"));
    }

    #[test]
    fn missing_value_rejected() {
        // non-boolean flags still require a value — a forgotten one must
        // not silently become the string "true"
        assert!(Cli::parse(&args(&["run", "--tiles"])).is_err());
        assert!(Cli::parse(&args(&["calibrate", "--out"])).is_err());
        assert!(Cli::parse(&args(&["run", "tiles", "3"])).is_err());
        assert!(Cli::parse(&args(&[])).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let c = Cli::parse(&args(&["run", "--tiles", "many"])).unwrap();
        assert!(c.run_config().is_err());
    }

    #[test]
    fn staging_flags_override_config() {
        let c = Cli::parse(&args(&[
            "run",
            "--staging-cap",
            "8",
            "--prefetch-depth",
            "2",
            "--read-latency-ms",
            "7",
            "--no-locality",
        ]))
        .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.staging_cap, crate::config::CacheCap::Chunks(8));
        assert_eq!(cfg.prefetch_depth, 2);
        assert_eq!(cfg.read_latency_ms, 7);
        assert!(!cfg.chunk_locality);
        // defaults keep locality on
        let cfg = Cli::parse(&args(&["run"])).unwrap().run_config().unwrap();
        assert!(cfg.chunk_locality);
    }

    #[test]
    fn byte_budget_caps_parse_from_flags() {
        let c = Cli::parse(&args(&["run", "--staging-cap", "64MB", "--spill-cap", "2GB"]))
            .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.staging_cap, crate::config::CacheCap::Bytes(64 << 20));
        assert_eq!(cfg.spill_cap, crate::config::CacheCap::Bytes(2u64 << 30));
        assert!(Cli::parse(&args(&["run", "--staging-cap", "64Mi"]))
            .unwrap()
            .run_config()
            .is_err());
    }

    #[test]
    fn tier_flags_override_config() {
        let c = Cli::parse(&args(&[
            "run",
            "--spill-dir",
            "/tmp/htap-spill",
            "--spill-cap",
            "16",
            "--no-replication",
            "--partition",
            "init",
        ]))
        .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.spill_dir.as_deref(), Some("/tmp/htap-spill"));
        assert_eq!(cfg.spill_cap, crate::config::CacheCap::Chunks(16));
        assert!(!cfg.replication);
        assert_eq!(cfg.partition, crate::config::PartitionMode::Init);
        // defaults: no spill tier, replication on, demand partition
        let cfg = Cli::parse(&args(&["run"])).unwrap().run_config().unwrap();
        assert!(cfg.spill_dir.is_none());
        assert!(cfg.replication);
        assert_eq!(cfg.partition, crate::config::PartitionMode::Demand);
        // bad values stay hard errors
        assert!(Cli::parse(&args(&["run", "--spill-cap", "zero"]))
            .unwrap()
            .run_config()
            .is_err());
        assert!(Cli::parse(&args(&["run", "--partition", "static"]))
            .unwrap()
            .run_config()
            .is_err());
    }

    #[test]
    fn membership_flags_override_config() {
        let c = Cli::parse(&args(&[
            "worker",
            "--heartbeat-ms",
            "100",
            "--lease-ms",
            "700",
            "--warm-restart",
        ]))
        .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.heartbeat_ms, 100);
        assert_eq!(cfg.lease_ms, 700);
        assert!(c.get_flag("warm-restart"));
        // defaults: heartbeat 500 / lease 3000, cold restart, no resume
        let c = Cli::parse(&args(&["worker"])).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.heartbeat_ms, RunConfig::default().heartbeat_ms);
        assert_eq!(cfg.lease_ms, RunConfig::default().lease_ms);
        assert!(!c.get_flag("warm-restart"));
        assert!(!c.get_flag("resume"));
        // validate() still rejects a heartbeat slower than the lease
        assert!(Cli::parse(&args(&["worker", "--heartbeat-ms", "5000"]))
            .unwrap()
            .run_config()
            .is_err());
        // --resume and --checkpoint-dir parse (consumed by main, not RunConfig)
        let c = Cli::parse(&args(&["manager", "--checkpoint-dir", "/tmp/ck", "--resume"]))
            .unwrap();
        assert_eq!(c.get("checkpoint-dir"), Some("/tmp/ck"));
        assert!(c.get_flag("resume"));
    }

    #[test]
    fn service_flags_override_config() {
        let c = Cli::parse(&args(&[
            "serve",
            "--max-jobs",
            "2",
            "--tenant-queue-depth",
            "3",
            "--tenant-quota",
            "4MB",
        ]))
        .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.max_jobs, 2);
        assert_eq!(cfg.tenant_queue_depth, 3);
        assert_eq!(cfg.tenant_quota, Some(crate::config::CacheCap::Bytes(4 << 20)));
        // defaults: 4 concurrent jobs, depth 8, no tenant fencing
        let cfg = Cli::parse(&args(&["serve"])).unwrap().run_config().unwrap();
        assert_eq!(cfg.max_jobs, RunConfig::default().max_jobs);
        assert_eq!(cfg.tenant_queue_depth, RunConfig::default().tenant_queue_depth);
        assert!(cfg.tenant_quota.is_none());
        // bad values stay hard errors
        assert!(Cli::parse(&args(&["serve", "--max-jobs", "0"]))
            .unwrap()
            .run_config()
            .is_err());
        assert!(Cli::parse(&args(&["serve", "--tenant-quota", "much"]))
            .unwrap()
            .run_config()
            .is_err());
        // submit/jobs/cancel/drain flags parse (consumed by main)
        let c = Cli::parse(&args(&[
            "submit",
            "--connect",
            "h:1",
            "--workflow",
            "wf.json",
            "--tenant",
            "alice",
            "--priority",
            "4",
        ]))
        .unwrap();
        assert_eq!(c.get("tenant"), Some("alice"));
        assert_eq!(c.get("priority"), Some("4"));
        let c = Cli::parse(&args(&["cancel", "--connect", "h:1", "--job", "7"])).unwrap();
        assert_eq!(c.get("job"), Some("7"));
        let c = Cli::parse(&args(&["worker", "--drain-on", "file:/tmp/drain"])).unwrap();
        assert_eq!(c.get("drain-on"), Some("file:/tmp/drain"));
    }

    #[test]
    fn observability_flags_parse() {
        let c = Cli::parse(&args(&["run", "--trace-out", "/tmp/trace.json"])).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("/tmp/trace.json"));
        // default: tracing off
        let cfg = Cli::parse(&args(&["run"])).unwrap().run_config().unwrap();
        assert!(cfg.trace_out.is_none());
        // a forgotten path stays a hard error
        assert!(Cli::parse(&args(&["run", "--trace-out"])).is_err());
        // htap top flags (consumed by main, not RunConfig)
        let c = Cli::parse(&args(&[
            "top",
            "--connect",
            "h:1",
            "--interval-ms",
            "250",
            "--iterations",
            "3",
        ]))
        .unwrap();
        assert_eq!(c.command, "top");
        assert_eq!(c.get("connect"), Some("h:1"));
        assert_eq!(c.get_usize("interval-ms", 1000).unwrap(), 250);
        assert_eq!(c.get_usize("iterations", 0).unwrap(), 3);
    }

    #[test]
    fn fault_and_failover_flags_parse() {
        let c = Cli::parse(&args(&[
            "run",
            "--fault-plan",
            "frame-drop=0.1#5,spill-io=1#2",
            "--fault-seed",
            "9",
        ]))
        .unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.fault_plan.as_deref(), Some("frame-drop=0.1#5,spill-io=1#2"));
        assert_eq!(cfg.fault_seed, 9);
        // defaults: no faults armed
        let cfg = Cli::parse(&args(&["run"])).unwrap().run_config().unwrap();
        assert!(cfg.fault_plan.is_none());
        assert_eq!(cfg.fault_seed, 0);
        // a malformed plan is rejected at run_config time, not mid-run
        assert!(Cli::parse(&args(&["run", "--fault-plan", "bogus-site=1"]))
            .unwrap()
            .run_config()
            .is_err());
        assert!(Cli::parse(&args(&["run", "--fault-plan", "frame-drop=2.0"]))
            .unwrap()
            .run_config()
            .is_err());
        // --standby is boolean; --primary/--promote-after-ms are consumed
        // by main, not RunConfig
        let c = Cli::parse(&args(&[
            "manager",
            "--standby",
            "--primary",
            "h:1",
            "--promote-after-ms",
            "500",
            "--checkpoint-dir",
            "/tmp/ck",
        ]))
        .unwrap();
        assert!(c.get_flag("standby"));
        assert_eq!(c.get("primary"), Some("h:1"));
        assert_eq!(c.get_usize("promote-after-ms", 3000).unwrap(), 500);
        // multi-address worker connect stays a single flag value
        let c = Cli::parse(&args(&["worker", "--connect", "h:1,h:2"])).unwrap();
        assert_eq!(c.get("connect"), Some("h:1,h:2"));
        // sim's fault mirror parses
        let c = Cli::parse(&args(&["sim", "--net-fault-rate", "0.2", "--fault-seed", "3"]))
            .unwrap();
        assert_eq!(c.get("net-fault-rate"), Some("0.2"));
    }

    #[test]
    fn defaults_apply() {
        let c = Cli::parse(&args(&["run"])).unwrap();
        let cfg = c.run_config().unwrap();
        assert_eq!(cfg.window, RunConfig::default().window);
        assert_eq!(c.get_usize("nodes", 4).unwrap(), 4);
    }
}
