//! Deterministic interleaving explorer (`cfg(htap_model)` builds only).
//!
//! A CHESS/loom-style *stateless model checker* for the concurrency core.
//! Code under test runs on real OS threads, but every synchronisation
//! operation — `Mutex::lock`, guard drop, `Condvar` wait/notify,
//! `thread` spawn/join/exit — is a **yield point** routed through a
//! virtual scheduler that keeps exactly one thread runnable at a time.
//! Each yield point where more than one thread could run next is a
//! *choice point*; [`explore`] replays a recorded prefix of choices,
//! extends it depth-first, and backtracks over the deepest untried
//! branch until the bounded schedule tree is exhausted.
//!
//! Bounding follows CHESS: switching away from a thread that could have
//! kept running costs one unit of the *preemption budget*
//! ([`ModelConfig::preemption_bound`]); forced switches (the active
//! thread blocked or exited) are free.  Small budgets (2–3) are known to
//! expose the vast majority of real concurrency bugs while keeping the
//! tree tractable.
//!
//! Deadlocks — including **lost wakeups**, which manifest as "work is
//! queued but every live thread is parked on a condvar" — are detected
//! when no thread is runnable while some are still live, and reported
//! with a per-thread diagnosis rather than hanging the test.
//!
//! Requirements on the closure under test: it must be deterministic
//! apart from scheduling (no wall-clock branching, no real randomness —
//! use `Policy::Fcfs`, not PATS, whose EWMA ordering is time-dependent),
//! and every thread it leaves blocked at the end is reported as a
//! deadlock, so shut subsystems down before returning.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{
    Arc, Condvar as StdCondvar, LockResult, Mutex as StdMutex, MutexGuard as StdMutexGuard,
    PoisonError,
};

// ---------------------------------------------------------------------------
// Panic payload used to tear down an aborted execution.
// ---------------------------------------------------------------------------

/// Panic payload unwound through every model thread when an execution is
/// aborted (deadlock detected, or another thread failed).  Never reaches
/// user code: [`explore`] recognises and swallows it.
struct ModelAbort;

fn install_quiet_abort_hook() {
    use std::sync::OnceLock;
    static HOOK: OnceLock<()> = OnceLock::new();
    HOOK.get_or_init(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().is::<ModelAbort>() {
                return; // expected teardown, not noise
            }
            default(info);
        }));
    });
}

// ---------------------------------------------------------------------------
// Scheduler state
// ---------------------------------------------------------------------------

const NONE: usize = usize::MAX;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TState {
    Runnable,
    BlockedLock(usize),
    BlockedCv(usize),
    BlockedJoin(usize),
    Finished,
}

#[derive(Clone, Copy, Debug)]
struct Choice {
    chosen: usize,
    options: usize,
}

struct Inner {
    names: Vec<String>,
    state: Vec<TState>,
    /// mutex id -> holding thread (None = free)
    held: Vec<Option<usize>>,
    /// condvar id -> FIFO of (waiting thread, mutex to reacquire)
    waiters: Vec<Vec<(usize, usize)>>,
    active: usize,
    live: usize,
    replay: Vec<usize>,
    trace: Vec<Choice>,
    step: usize,
    preemptions_left: usize,
    deadlock: Option<String>,
    /// first non-ModelAbort panic message from any model thread
    failure: Option<String>,
    abort: bool,
}

pub(crate) struct Sched {
    epoch: u64,
    m: StdMutex<Inner>,
    cv: StdCondvar,
}

fn next_epoch() -> u64 {
    static EPOCH: AtomicU64 = AtomicU64::new(1);
    EPOCH.fetch_add(1, Ordering::Relaxed)
}

thread_local! {
    static CURRENT: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

#[derive(Clone)]
struct Ctx {
    sched: Arc<Sched>,
    tid: usize,
}

fn current() -> Option<Ctx> {
    CURRENT.with(|c| c.borrow().clone())
}

fn lock_inner(m: &StdMutex<Inner>) -> StdMutexGuard<'_, Inner> {
    // the scheduler's own mutex: a poisoner already recorded its failure
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Sched {
    fn new(replay: Vec<usize>, preemption_bound: usize) -> Arc<Self> {
        Arc::new(Sched {
            epoch: next_epoch(),
            m: StdMutex::new(Inner {
                names: Vec::new(),
                state: Vec::new(),
                held: Vec::new(),
                waiters: Vec::new(),
                active: 0, // root thread is always tid 0
                live: 0,
                replay,
                trace: Vec::new(),
                step: 0,
                preemptions_left: preemption_bound,
                deadlock: None,
                failure: None,
                abort: false,
            }),
            cv: StdCondvar::new(),
        })
    }

    fn register_thread(&self, name: &str) -> usize {
        let mut g = lock_inner(&self.m);
        g.names.push(name.to_string());
        g.state.push(TState::Runnable);
        g.live += 1;
        g.state.len() - 1
    }

    fn register_mutex(&self) -> usize {
        let mut g = lock_inner(&self.m);
        g.held.push(None);
        g.held.len() - 1
    }

    fn register_condvar(&self) -> usize {
        let mut g = lock_inner(&self.m);
        g.waiters.push(Vec::new());
        g.waiters.len() - 1
    }

    /// Pick the next active thread.  `forced` means the calling thread can
    /// no longer run (blocked or exiting), so the switch is free; otherwise
    /// switching away consumes preemption budget.  Called with the inner
    /// lock held.
    fn pick_next(&self, g: &mut Inner, me: usize, forced: bool) {
        if g.abort {
            self.cv.notify_all();
            return;
        }
        let runnable: Vec<usize> = (0..g.state.len())
            .filter(|&t| matches!(g.state[t], TState::Runnable))
            .collect();
        if runnable.is_empty() {
            if g.live == 0 {
                g.active = NONE;
            } else {
                g.deadlock = Some(describe(g));
                g.abort = true;
            }
            self.cv.notify_all();
            return;
        }
        let options: Vec<usize> = if !forced {
            if g.preemptions_left == 0 {
                vec![me]
            } else {
                let mut v = vec![me];
                v.extend(runnable.iter().copied().filter(|&t| t != me));
                v
            }
        } else {
            runnable
        };
        let idx = if g.step < g.replay.len() {
            g.replay[g.step].min(options.len() - 1)
        } else {
            0
        };
        if options.len() > 1 {
            g.trace.push(Choice { chosen: idx, options: options.len() });
            g.step += 1;
        }
        if !forced && idx > 0 {
            g.preemptions_left -= 1;
        }
        g.active = options[idx];
        self.cv.notify_all();
    }

    /// Block until this thread is the active runnable one, or the
    /// execution aborts (in which case unwind with [`ModelAbort`]).
    fn wait_my_turn<'a>(
        &'a self,
        mut g: StdMutexGuard<'a, Inner>,
        me: usize,
    ) -> StdMutexGuard<'a, Inner> {
        loop {
            if g.abort {
                drop(g);
                std::panic::panic_any(ModelAbort);
            }
            if g.active == me && matches!(g.state[me], TState::Runnable) {
                return g;
            }
            g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    fn yield_point<'a>(
        &'a self,
        mut g: StdMutexGuard<'a, Inner>,
        me: usize,
        forced: bool,
    ) -> StdMutexGuard<'a, Inner> {
        self.pick_next(&mut g, me, forced);
        self.wait_my_turn(g, me)
    }

    // ---- shim operations ------------------------------------------------

    fn acquire(&self, mid: usize, me: usize) {
        let g = lock_inner(&self.m);
        // pre-acquire preemption point: someone else may take the lock first
        let mut g = self.yield_point(g, me, false);
        loop {
            if g.held[mid].is_none() {
                g.held[mid] = Some(me);
                return;
            }
            g.state[me] = TState::BlockedLock(mid);
            g = self.yield_point(g, me, true);
        }
    }

    fn release(&self, mid: usize, me: usize) {
        let mut g = lock_inner(&self.m);
        g.held[mid] = None;
        wake_lock_waiters(&mut g, mid);
        if std::thread::panicking() {
            // unwinding through a guard drop: hand off without choice points
            self.pick_next(&mut g, me, true);
            return;
        }
        // post-release preemption point: this is the classic window where a
        // contender may slip in between `drop(guard)` and a notify
        let g = self.yield_point(g, me, false);
        drop(g);
    }

    fn cv_wait(&self, cvid: usize, mid: usize, me: usize) {
        let mut g = lock_inner(&self.m);
        // atomically release the mutex and join the wait queue
        g.held[mid] = None;
        wake_lock_waiters(&mut g, mid);
        g.state[me] = TState::BlockedCv(cvid);
        g.waiters[cvid].push((me, mid));
        let mut g = self.yield_point(g, me, true); // parked until notified
        // reacquire the mutex before returning, like a real condvar
        loop {
            if g.held[mid].is_none() {
                g.held[mid] = Some(me);
                return;
            }
            g.state[me] = TState::BlockedLock(mid);
            g = self.yield_point(g, me, true);
        }
    }

    fn notify(&self, cvid: usize, me: usize, all: bool) {
        let mut g = lock_inner(&self.m);
        loop {
            if g.waiters[cvid].is_empty() {
                break;
            }
            let (t, mx) = g.waiters[cvid].remove(0); // FIFO wakeup
            g.state[t] = if g.held[mx].is_none() {
                TState::Runnable
            } else {
                TState::BlockedLock(mx)
            };
            if !all {
                break;
            }
        }
        let g = self.yield_point(g, me, false);
        drop(g);
    }

    fn post_spawn(&self, me: usize) {
        let g = lock_inner(&self.m);
        // spawn is a yield point: the child may be scheduled before the parent
        let g = self.yield_point(g, me, false);
        drop(g);
    }

    fn first_turn(&self, me: usize) {
        let g = lock_inner(&self.m);
        let g = self.wait_my_turn(g, me);
        drop(g);
    }

    fn join_wait(&self, target: usize, me: usize) {
        let g = lock_inner(&self.m);
        let mut g = self.yield_point(g, me, false);
        loop {
            if matches!(g.state[target], TState::Finished) {
                return;
            }
            g.state[me] = TState::BlockedJoin(target);
            g = self.yield_point(g, me, true);
        }
    }

    fn record_failure(&self, msg: String) {
        let mut g = lock_inner(&self.m);
        if g.failure.is_none() {
            g.failure = Some(msg);
        }
        g.abort = true;
        self.cv.notify_all();
    }

    fn thread_exit(&self, me: usize) {
        let mut g = lock_inner(&self.m);
        g.state[me] = TState::Finished;
        g.live -= 1;
        for t in 0..g.state.len() {
            if g.state[t] == TState::BlockedJoin(me) {
                g.state[t] = TState::Runnable;
            }
        }
        if g.live == 0 {
            g.active = NONE;
            self.cv.notify_all();
            return;
        }
        // hand off; the exiting thread never waits again
        self.pick_next(&mut g, me, true);
    }

    fn wait_quiescent(&self) {
        let mut g = lock_inner(&self.m);
        while g.live > 0 {
            g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    fn take_outcome(&self) -> (Vec<Choice>, Option<String>, Option<String>) {
        let mut g = lock_inner(&self.m);
        (std::mem::take(&mut g.trace), g.deadlock.take(), g.failure.take())
    }

    // ---- object identity across executions ------------------------------

    /// Resolve an object's per-execution id from its tag cell, registering
    /// it on first use within this execution.  Only the active thread runs,
    /// so plain load/store ordering suffices.
    fn resolve(&self, tag: &AtomicU64, kind: ObjKind) -> usize {
        let t = tag.load(Ordering::Relaxed);
        if t >> 24 == self.epoch {
            return (t & 0xFF_FFFF) as usize - 1;
        }
        let id = match kind {
            ObjKind::Mutex => self.register_mutex(),
            ObjKind::Condvar => self.register_condvar(),
        };
        tag.store((self.epoch << 24) | (id as u64 + 1), Ordering::Relaxed);
        id
    }
}

#[derive(Clone, Copy)]
enum ObjKind {
    Mutex,
    Condvar,
}

fn wake_lock_waiters(g: &mut Inner, mid: usize) {
    for t in 0..g.state.len() {
        if g.state[t] == TState::BlockedLock(mid) {
            g.state[t] = TState::Runnable;
        }
    }
}

fn describe(g: &Inner) -> String {
    let mut out = String::from("all live threads are blocked:");
    for t in 0..g.state.len() {
        let s = match g.state[t] {
            TState::Runnable => continue,
            TState::Finished => continue,
            TState::BlockedLock(m) => {
                let holder = g.held[m]
                    .map(|h| g.names[h].clone())
                    .unwrap_or_else(|| "<free>".into());
                format!("waiting for mutex m{m} (held by {holder})")
            }
            TState::BlockedCv(c) => format!("parked on condvar c{c} (no wakeup coming)"),
            TState::BlockedJoin(j) => format!("joining thread '{}'", g.names[j]),
        };
        out.push_str(&format!("\n  '{}': {}", g.names[t], s));
    }
    out
}

// ---------------------------------------------------------------------------
// Public shim types
// ---------------------------------------------------------------------------

/// Model-checked mutex: identical API to [`std::sync::Mutex`] for the
/// subset the runtime uses.  Outside an [`explore`] execution it behaves
/// exactly like std (passthrough), so the whole ordinary test suite still
/// runs under `--features htap-model`.
pub struct Mutex<T: ?Sized> {
    tag: AtomicU64,
    inner: StdMutex<T>,
}

pub struct MutexGuard<'a, T: ?Sized> {
    // Option so Condvar::wait can take the std guard out and put a fresh
    // one back without running our Drop logic in between
    g: Option<StdMutexGuard<'a, T>>,
    lock: &'a Mutex<T>,
    model: Option<Ctx>,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Self {
        Mutex { tag: AtomicU64::new(0), inner: StdMutex::new(t) }
    }
}

impl<T: ?Sized> Mutex<T> {
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match current() {
            None => match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { g: Some(g), lock: self, model: None }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    g: Some(p.into_inner()),
                    lock: self,
                    model: None,
                })),
            },
            Some(ctx) => {
                let mid = ctx.sched.resolve(&self.tag, ObjKind::Mutex);
                ctx.sched.acquire(mid, ctx.tid);
                // the virtual scheduler has granted us the lock; the real
                // mutex is free (at most transiently contended), and model
                // threads never leave it poisoned without aborting first
                let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
                Ok(MutexGuard { g: Some(g), lock: self, model: Some(ctx) })
            }
        }
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        match self.inner.get_mut() {
            Ok(t) => Ok(t),
            Err(p) => Err(PoisonError::new(p.into_inner())),
        }
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

impl<T: ?Sized + std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mutex").finish_non_exhaustive()
    }
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.g.as_deref().expect("guard present")
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.g.as_deref_mut().expect("guard present")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // release the real mutex before telling the scheduler, so a thread
        // granted the virtual lock next never blocks on the OS mutex
        self.g = None;
        if let Some(ctx) = self.model.take() {
            let mid = ctx.sched.resolve(&self.lock.tag, ObjKind::Mutex);
            ctx.sched.release(mid, ctx.tid);
        }
    }
}

/// Model-checked condvar; passthrough to std outside an execution.
pub struct Condvar {
    tag: AtomicU64,
    inner: StdCondvar,
}

impl Condvar {
    pub const fn new() -> Self {
        Condvar { tag: AtomicU64::new(0), inner: StdCondvar::new() }
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match guard.model.clone() {
            None => {
                let std_g = guard.g.take().expect("guard present");
                let lock = guard.lock;
                std::mem::forget(guard); // std guard already extracted
                match self.inner.wait(std_g) {
                    Ok(g) => Ok(MutexGuard { g: Some(g), lock, model: None }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        g: Some(p.into_inner()),
                        lock,
                        model: None,
                    })),
                }
            }
            Some(ctx) => {
                let lock = guard.lock;
                let mid = ctx.sched.resolve(&lock.tag, ObjKind::Mutex);
                let cvid = ctx.sched.resolve(&self.tag, ObjKind::Condvar);
                // release the real mutex, then the virtual one + park
                guard.g = None;
                std::mem::forget(guard);
                ctx.sched.cv_wait(cvid, mid, ctx.tid);
                // virtual mutex reacquired; take the real one to match
                let g = lock.inner.lock().unwrap_or_else(PoisonError::into_inner);
                Ok(MutexGuard { g: Some(g), lock, model: Some(ctx) })
            }
        }
    }

    pub fn wait_while<'a, T, F>(
        &self,
        mut guard: MutexGuard<'a, T>,
        mut condition: F,
    ) -> LockResult<MutexGuard<'a, T>>
    where
        F: FnMut(&mut T) -> bool,
    {
        while condition(&mut guard) {
            guard = match self.wait(guard) {
                Ok(g) => g,
                Err(p) => return Err(p),
            };
        }
        Ok(guard)
    }

    pub fn notify_one(&self) {
        if let Some(ctx) = current() {
            let cvid = ctx.sched.resolve(&self.tag, ObjKind::Condvar);
            ctx.sched.notify(cvid, ctx.tid, false);
        } else {
            self.inner.notify_one();
        }
    }

    pub fn notify_all(&self) {
        if let Some(ctx) = current() {
            let cvid = ctx.sched.resolve(&self.tag, ObjKind::Condvar);
            ctx.sched.notify(cvid, ctx.tid, true);
        } else {
            self.inner.notify_all();
        }
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

pub mod thread {
    //! Model-checked subset of [`std::thread`]; passthrough outside an
    //! execution.

    use super::{current, Ctx, Sched, TState, CURRENT};
    use std::sync::Arc;

    pub struct Builder {
        name: Option<String>,
    }

    impl Builder {
        #[allow(clippy::new_without_default)]
        pub fn new() -> Self {
            Builder { name: None }
        }

        pub fn name(mut self, name: String) -> Self {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            let mut b = std::thread::Builder::new();
            let name = self.name.clone().unwrap_or_else(|| "model".into());
            if let Some(n) = self.name {
                b = b.name(n);
            }
            match current() {
                None => {
                    let h = b.spawn(f)?;
                    Ok(JoinHandle { inner: h, model: None })
                }
                Some(parent) => {
                    let tid = parent.sched.register_thread(&name);
                    let sched = parent.sched.clone();
                    let h = b.spawn(move || super::run_model_thread(sched, tid, f))?;
                    parent.sched.post_spawn(parent.tid);
                    Ok(JoinHandle { inner: h, model: Some((parent.sched, tid)) })
                }
            }
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        // std::thread::spawn panics on spawn failure too
        // lint: allow(panic) — mirrors std::thread::spawn semantics
        Builder::new().spawn(f).expect("failed to spawn thread")
    }

    pub struct JoinHandle<T> {
        pub(super) inner: std::thread::JoinHandle<T>,
        pub(super) model: Option<(Arc<Sched>, usize)>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            if let Some((sched, target)) = &self.model {
                let me = current().map(|c| c.tid).unwrap_or(usize::MAX);
                if me != usize::MAX {
                    sched.join_wait(*target, me);
                }
                // target Finished: the OS thread is exiting; real join is quick
            }
            self.inner.join()
        }

        pub fn is_finished(&self) -> bool {
            if let Some((sched, target)) = &self.model {
                let g = super::lock_inner(&sched.m);
                return matches!(g.state[*target], TState::Finished);
            }
            self.inner.is_finished()
        }
    }

    /// Cooperative yield: a bare preemption point inside an execution, a
    /// std yield outside.
    pub fn yield_now() {
        if let Some(ctx) = current() {
            ctx.sched.post_spawn(ctx.tid); // plain unforced yield point
        } else {
            std::thread::yield_now();
        }
    }

    pub(super) fn enter(sched: Arc<Sched>, tid: usize) {
        CURRENT.with(|c| *c.borrow_mut() = Some(Ctx { sched, tid }));
    }

    pub(super) fn exit_ctx() {
        CURRENT.with(|c| *c.borrow_mut() = None);
    }
}

/// Body of every model-managed OS thread: register context, wait for the
/// first turn, run, record panics, and always hand control back.
fn run_model_thread<F, T>(sched: Arc<Sched>, tid: usize, f: F) -> T
where
    F: FnOnce() -> T,
{
    struct Registration {
        sched: Arc<Sched>,
        tid: usize,
    }
    impl Drop for Registration {
        fn drop(&mut self) {
            self.sched.thread_exit(self.tid);
            thread::exit_ctx();
        }
    }

    thread::enter(sched.clone(), tid);
    let _reg = Registration { sched: sched.clone(), tid };
    sched.first_turn(tid);
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(v) => v,
        Err(payload) => {
            if !payload.is::<ModelAbort>() {
                sched.record_failure(panic_message(&payload));
            }
            std::panic::resume_unwind(payload);
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

// ---------------------------------------------------------------------------
// Explorer
// ---------------------------------------------------------------------------

/// Exploration bounds.
#[derive(Clone, Copy, Debug)]
pub struct ModelConfig {
    /// Stop after this many distinct schedules even if the tree is not
    /// exhausted (env override: `HTAP_MODEL_SCHEDULES`).
    pub max_schedules: usize,
    /// CHESS preemption budget per execution (env override:
    /// `HTAP_MODEL_PREEMPTIONS`).
    pub preemption_bound: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        let env_us = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|s| s.parse().ok()).unwrap_or(d)
        };
        ModelConfig {
            max_schedules: env_us("HTAP_MODEL_SCHEDULES", 4000),
            preemption_bound: env_us("HTAP_MODEL_PREEMPTIONS", 2),
        }
    }
}

/// Outcome of an exploration.
#[derive(Debug)]
pub struct Report {
    /// Distinct schedules executed.
    pub schedules: usize,
    /// True when the bounded schedule tree was fully explored.
    pub exhausted: bool,
    /// Executions that ended in a deadlock / lost wakeup.
    pub deadlocks: usize,
    /// Diagnosis of the first deadlock found, with its schedule.
    pub first_deadlock: Option<String>,
}

/// Run `f` under the virtual scheduler once per schedule, enumerating the
/// bounded interleaving tree depth-first.
///
/// * A **panic** in `f` (e.g. a failed assertion) fails fast: the
///   triggering schedule is printed and the panic is re-raised.
/// * **Deadlocks** (including lost wakeups) are *counted*, not panicked,
///   so tests can both assert `deadlocks == 0` on correct code and
///   assert `deadlocks > 0` on intentionally broken protocols.
pub fn explore<F>(name: &str, cfg: ModelConfig, f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    install_quiet_abort_hook();
    let f = Arc::new(f);
    let mut replay: Vec<usize> = Vec::new();
    let mut schedules = 0usize;
    let mut deadlocks = 0usize;
    let mut first_deadlock: Option<String> = None;

    loop {
        schedules += 1;
        let sched = Sched::new(replay.clone(), cfg.preemption_bound);
        let root = sched.register_thread("model-root");
        debug_assert_eq!(root, 0);
        let (s2, ff) = (sched.clone(), f.clone());
        let handle = std::thread::Builder::new()
            .name(format!("model-root-{name}"))
            .spawn(move || run_model_thread(s2, root, move || ff()))
            .expect("spawn model root thread");
        sched.wait_quiescent();
        let joined = handle.join();
        let (trace, deadlock, failure) = sched.take_outcome();

        if let Some(msg) = failure {
            let sched_str: Vec<usize> = trace.iter().map(|c| c.chosen).collect();
            eprintln!(
                "model '{name}': thread panicked under schedule {sched_str:?} \
                 (execution {schedules}): {msg}"
            );
            match joined {
                Err(payload) => std::panic::resume_unwind(payload),
                Ok(_) => panic!("model '{name}': {msg}"),
            }
        }
        if let Some(d) = deadlock {
            deadlocks += 1;
            if first_deadlock.is_none() {
                let sched_str: Vec<usize> = trace.iter().map(|c| c.chosen).collect();
                first_deadlock =
                    Some(format!("schedule {sched_str:?} (execution {schedules}): {d}"));
            }
        }

        match next_replay(&trace) {
            None => {
                return Report { schedules, exhausted: true, deadlocks, first_deadlock };
            }
            Some(r) => replay = r,
        }
        if schedules >= cfg.max_schedules {
            return Report { schedules, exhausted: false, deadlocks, first_deadlock };
        }
    }
}

/// Depth-first backtracking: flip the deepest choice with an untried
/// branch; `None` when the tree is exhausted.
fn next_replay(trace: &[Choice]) -> Option<Vec<usize>> {
    for i in (0..trace.len()).rev() {
        if trace[i].chosen + 1 < trace[i].options {
            let mut r: Vec<usize> = trace[..i].iter().map(|c| c.chosen).collect();
            r.push(trace[i].chosen + 1);
            return Some(r);
        }
    }
    None
}

/// Convenience map for tests: count how often each distinct outcome value
/// is observed across schedules.
pub fn tally<K: std::hash::Hash + Eq>(into: &mut HashMap<K, usize>, k: K) {
    *into.entry(k).or_insert(0) += 1;
}

// ---------------------------------------------------------------------------
// Self-tests (run under `cargo test --features htap-model`)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn passthrough_outside_execution() {
        let m = Mutex::new(5u32);
        {
            let mut g = m.lock().unwrap();
            *g += 1;
        }
        assert_eq!(*m.lock().unwrap(), 6);
        let cv = Condvar::new();
        cv.notify_one(); // no waiters: no-op, must not panic
        let h = thread::spawn(|| 41 + 1);
        assert_eq!(h.join().unwrap(), 42);
    }

    #[test]
    fn explores_multiple_interleavings_of_two_increments() {
        // Two threads doing read-modify-write under a mutex: the final
        // value is always 2, but the explorer must drive >1 schedule.
        let report = explore("two-inc", ModelConfig::default(), || {
            let m = Arc::new(Mutex::new(0u32));
            let (a, b) = (m.clone(), m.clone());
            let t1 = thread::spawn(move || *a.lock().unwrap() += 1);
            let t2 = thread::spawn(move || *b.lock().unwrap() += 1);
            t1.join().unwrap();
            t2.join().unwrap();
            assert_eq!(*m.lock().unwrap(), 2);
        });
        assert!(report.schedules > 1, "expected >1 schedule, got {}", report.schedules);
        assert_eq!(report.deadlocks, 0, "{:?}", report.first_deadlock);
        assert!(report.exhausted);
    }

    #[test]
    fn detects_lost_wakeup() {
        // Classic missed-wakeup bug: the waiter checks the flag, then
        // waits — but the signaller may set the flag *and* notify in the
        // window between check and wait.  Some schedule must deadlock.
        let report = explore("lost-wakeup", ModelConfig::default(), || {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = pair.clone();
            let signaller = thread::spawn(move || {
                let (m, cv) = &*p2;
                *m.lock().unwrap() = true;
                cv.notify_one();
            });
            {
                let (m, cv) = &*pair;
                let ready = { *m.lock().unwrap() }; // buggy: check outside wait
                if !ready {
                    let g = m.lock().unwrap();
                    let _g = cv.wait(g).unwrap(); // may sleep forever
                }
            }
            signaller.join().unwrap();
        });
        assert!(
            report.deadlocks > 0,
            "explorer failed to find the seeded lost wakeup in {} schedules",
            report.schedules
        );
    }

    #[test]
    fn correct_condvar_protocol_has_no_deadlock() {
        let report = explore("cv-ok", ModelConfig::default(), || {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = pair.clone();
            let signaller = thread::spawn(move || {
                let (m, cv) = &*p2;
                *m.lock().unwrap() = true;
                cv.notify_one();
            });
            {
                let (m, cv) = &*pair;
                let mut g = m.lock().unwrap();
                while !*g {
                    g = cv.wait(g).unwrap(); // re-check under the lock
                }
            }
            signaller.join().unwrap();
        });
        assert_eq!(report.deadlocks, 0, "{:?}", report.first_deadlock);
        assert!(report.exhausted);
    }

    #[test]
    fn detects_lock_order_deadlock() {
        // AB-BA deadlock: must be found within the preemption budget.
        let report = explore("ab-ba", ModelConfig::default(), || {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (a.clone(), b.clone());
            let t = thread::spawn(move || {
                let _ga = a2.lock().unwrap();
                let _gb = b2.lock().unwrap();
            });
            {
                let _gb = b.lock().unwrap();
                let _ga = a.lock().unwrap();
            }
            let _ = t.join();
        });
        assert!(report.deadlocks > 0, "AB-BA deadlock not found");
    }

    #[test]
    fn schedules_are_deterministic_under_replay() {
        // Same closure, same config → same schedule count (replay works).
        static RUNS: AtomicUsize = AtomicUsize::new(0);
        let run = || {
            explore("det", ModelConfig { max_schedules: 500, preemption_bound: 2 }, || {
                RUNS.fetch_add(1, Ordering::Relaxed);
                let m = Arc::new(Mutex::new(0u32));
                let m2 = m.clone();
                let t = thread::spawn(move || *m2.lock().unwrap() += 1);
                *m.lock().unwrap() += 1;
                t.join().unwrap();
            })
            .schedules
        };
        assert_eq!(run(), run());
    }
}
