//! Synchronisation shim for the concurrency core (WRM + staging cache).
//!
//! In production builds this module is a **zero-cost re-export** of
//! `std::sync::{Mutex, Condvar}` and `std::thread` — there is no wrapper
//! type, no branch, nothing between the caller and std.  Under
//! `cfg(htap_model)` (or the `htap-model` cargo feature) the same names
//! resolve to the deterministic-interleaving types in [`model`]: a virtual
//! scheduler serialises every thread at each lock / unlock / wait / notify
//! / spawn boundary and enumerates bounded interleavings, so
//! `rust/tests/model_wrm.rs` can assert "no deadlock, no lost wakeup"
//! over the dispatch protocol instead of hoping.  See docs/analysis.md.
//!
//! The module also carries two small discipline helpers used on the worker
//! hot paths regardless of build:
//!
//! * [`lock_or_poisoned`] / [`lock_clean`] — poisoning policy.  A poisoned
//!   mutex means a thread panicked *inside* a critical section; the WRM
//!   converts that into an error completion (the same policy as op
//!   panics), and best-effort stats holders just recover the guard.
//! * [`HoldWatchdog`] — debug-build lock-hold-time watchdog.  The zero-copy
//!   dispatch discipline (see `coordinator::wrm`) promises microsecond-scale
//!   critical sections; the watchdog times each marked section and warns
//!   (or, with `HTAP_LOCK_STRICT=1`, panics) when one blows its budget, so
//!   a discipline regression that slips past `cargo xtask lint` still
//!   surfaces in any debug test run.

#[cfg(any(htap_model, feature = "htap-model"))]
pub mod model;

#[cfg(not(any(htap_model, feature = "htap-model")))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(not(any(htap_model, feature = "htap-model")))]
pub mod thread {
    //! Re-export of [`std::thread`] (production builds).
    pub use std::thread::*;
}

#[cfg(any(htap_model, feature = "htap-model"))]
pub use model::{Condvar, Mutex, MutexGuard};

#[cfg(any(htap_model, feature = "htap-model"))]
pub use model::thread;

/// Marker error for [`lock_or_poisoned`]: the mutex was poisoned by a
/// panic inside a critical section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Poisoned;

impl std::fmt::Display for Poisoned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "mutex poisoned by a panicking critical section")
    }
}

/// Acquire `m`, surfacing poisoning as an error instead of a panic.
///
/// Hot-path callers (WRM device threads, the staging cache's demand path)
/// turn the error into an **error completion** so one panicked critical
/// section aborts the run cleanly instead of cascading `unwrap` panics
/// through every thread that touches the lock afterwards.
pub fn lock_or_poisoned<T>(m: &Mutex<T>) -> std::result::Result<MutexGuard<'_, T>, Poisoned> {
    m.lock().map_err(|_| Poisoned)
}

/// Acquire `m`, recovering the guard if the mutex is poisoned.
///
/// For best-effort state (metrics deltas, EWMA profile stats, the
/// manager's bookkeeping) where the data is plain counters/maps and
/// continuing with the last consistent-enough view beats killing the
/// whole process.  The first recovery per process logs a warning so
/// poisoning never goes completely silent.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => {
            warn_poison_once();
            p.into_inner()
        }
    }
}

fn warn_poison_once() {
    use std::sync::atomic::{AtomicBool, Ordering};
    static WARNED: AtomicBool = AtomicBool::new(false);
    if !WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "htap: recovered a poisoned mutex (a critical section panicked); \
             continuing best-effort — see docs/analysis.md"
        );
    }
}

/// Debug-build lock-hold-time watchdog.
///
/// Construct one immediately after acquiring a marked critical section:
///
/// ```ignore
/// let mut inner = sync::lock_or_poisoned(&self.inner)?;
/// let _hold = HoldWatchdog::new("wrm.finish_op");
/// ```
///
/// Declared *after* the guard, it drops *before* the guard releases, so it
/// measures the true hold time.  Release builds and `htap_model` builds
/// compile it to nothing.  Budget: `HTAP_LOCK_BUDGET_US` (default 250 µs —
/// generous for O(ports) pointer work even in unoptimised builds); set
/// `HTAP_LOCK_STRICT=1` to turn the warning into a panic (which the
/// surrounding poisoning policy then converts into an error completion).
///
/// Sections that legitimately touch local disk under their lock (the
/// spill tier) use [`HoldWatchdog::with_budget_us`] with a millisecond
/// budget instead.
pub struct HoldWatchdog {
    #[cfg(all(debug_assertions, not(any(htap_model, feature = "htap-model"))))]
    inner: watchdog_impl::Active,
}

impl HoldWatchdog {
    #[inline]
    pub fn new(site: &'static str) -> Self {
        Self::with_budget_us(site, 0)
    }

    /// Watchdog with an explicit budget in microseconds (0 = the default
    /// `HTAP_LOCK_BUDGET_US` budget).
    #[inline]
    pub fn with_budget_us(site: &'static str, budget_us: u64) -> Self {
        #[cfg(all(debug_assertions, not(any(htap_model, feature = "htap-model"))))]
        {
            HoldWatchdog { inner: watchdog_impl::Active::new(site, budget_us) }
        }
        #[cfg(not(all(debug_assertions, not(any(htap_model, feature = "htap-model")))))]
        {
            let _ = (site, budget_us);
            HoldWatchdog {}
        }
    }
}

#[cfg(all(debug_assertions, not(any(htap_model, feature = "htap-model"))))]
mod watchdog_impl {
    use std::time::{Duration, Instant};

    pub struct Active {
        site: &'static str,
        budget: Duration,
        start: Instant,
    }

    fn default_budget_us() -> u64 {
        use std::sync::OnceLock;
        static BUDGET: OnceLock<u64> = OnceLock::new();
        *BUDGET.get_or_init(|| {
            std::env::var("HTAP_LOCK_BUDGET_US")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(250)
        })
    }

    fn strict() -> bool {
        use std::sync::OnceLock;
        static STRICT: OnceLock<bool> = OnceLock::new();
        *STRICT.get_or_init(|| {
            std::env::var("HTAP_LOCK_STRICT").map(|v| v == "1").unwrap_or(false)
        })
    }

    impl Active {
        pub fn new(site: &'static str, budget_us: u64) -> Self {
            let budget_us = if budget_us == 0 { default_budget_us() } else { budget_us };
            Active {
                site,
                budget: Duration::from_micros(budget_us),
                start: Instant::now(),
            }
        }
    }

    impl Drop for Active {
        fn drop(&mut self) {
            let held = self.start.elapsed();
            if held <= self.budget {
                return;
            }
            // `panic!` here fires while the caller still holds the lock, so
            // the mutex poisons and the lock_or_poisoned policy turns the
            // regression into an error completion — exactly the cascade the
            // discipline is meant to prevent, surfaced deliberately.
            if strict() && !std::thread::panicking() {
                // lint: allow(panic) — opt-in strict mode (HTAP_LOCK_STRICT)
                panic!(
                    "lock-hold budget blown at {}: held {held:?} (budget {:?})",
                    self.site, self.budget
                );
            }
            eprintln!(
                "htap: lock-hold watchdog: {} held {held:?} (budget {:?}) — \
                 a critical section is doing too much under the mutex",
                self.site, self.budget
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_or_poisoned_surfaces_poison_as_error() {
        let m = std::sync::Arc::new(Mutex::new(0u32));
        let m2 = m.clone();
        // poison it: panic while holding the guard on another thread
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(lock_or_poisoned(&m).is_err());
        // lock_clean recovers the guard and the data
        assert_eq!(*lock_clean(&m), 0);
    }

    #[test]
    fn lock_or_poisoned_passes_through_clean_locks() {
        let m = Mutex::new(7u32);
        assert_eq!(*lock_or_poisoned(&m).unwrap(), 7);
        *lock_clean(&m) = 9;
        assert_eq!(*lock_or_poisoned(&m).unwrap(), 9);
    }

    #[test]
    fn watchdog_is_silent_within_budget() {
        // a generous explicit budget: construction + drop must not warn or
        // panic even under HTAP_LOCK_STRICT in slow debug environments
        let _w = HoldWatchdog::with_budget_us("test.site", 10_000_000);
    }
}
