//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the accelerator half of every *function variant*: the python
//! build step (`make artifacts`) lowers each JAX/Pallas graph to HLO text;
//! this module loads, compiles (once) and executes them through the `xla`
//! crate's PJRT CPU client.  Python never runs on the request path.
//!
//! PJRT wrapper types hold raw pointers and are not `Send`, so every device
//! thread owns its own [`DeviceExecutor`] — mirroring the paper's design of
//! one GPU-controller thread per GPU.

pub mod artifacts;
pub mod calibrate;
pub mod pjrt;
pub mod sync;
pub mod tensor;

pub use artifacts::{ArtifactManifest, ModuleMeta};
pub use calibrate::{CalibrationConfig, ProfileStore, SharedProfiles};
pub use pjrt::DeviceExecutor;
pub use tensor::{HostTensor, Value};
