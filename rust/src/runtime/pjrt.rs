//! Per-device PJRT executor: compile-once / execute-many + a device-resident
//! buffer cache.
//!
//! The paper's GPU controller threads own a CUDA context, launch kernels and
//! move data over PCIe; here each accelerator device thread owns a
//! [`DeviceExecutor`] (PJRT wrapper types are not `Send`), which:
//!
//! * compiles each HLO artifact lazily, once, and caches the executable;
//! * implements the three data-movement phases the paper optimises —
//!   **upload** (host value -> PJRT buffer), **process** (`execute_b`),
//!   **download** (buffer -> host value) — with byte/transfer accounting so
//!   the data-locality (DL) optimisation is observable;
//! * keeps single-output results **device-resident** (keyed buffers) so a
//!   dependent operation scheduled on the same device reuses them without a
//!   round trip — the DL mechanism of paper §IV-C.

use super::artifacts::ArtifactManifest;
use super::tensor::{HostTensor, Value};
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Identifies a device-resident payload (an op output kept on the device).
pub type PayloadKey = u64;

static NEXT_KEY: AtomicU64 = AtomicU64::new(1);

fn fresh_key() -> PayloadKey {
    NEXT_KEY.fetch_add(1, Ordering::Relaxed)
}

/// Input to an accelerator execution: either host data (must be uploaded)
/// or a payload already resident on this device.
pub enum ExecInput<'a> {
    Host(&'a Value),
    Resident(PayloadKey),
}

/// Transfer / execution counters (drives EXPERIMENTS.md data-movement plots).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub executions: u64,
    pub uploads: u64,
    pub downloads: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub cache_hits: u64,
    pub compile_count: u64,
}

struct Resident {
    buffer: xla::PjRtBuffer,
    /// Number of outputs encoded in the buffer (1 = plain array root).
    n_outputs: usize,
    bytes: usize,
}

/// One device's compiled-artifact cache + resident-buffer store.
pub struct DeviceExecutor {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    executables: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
    resident: HashMap<PayloadKey, Resident>,
    pub stats: ExecStats,
}

impl DeviceExecutor {
    /// Create an executor bound to the PJRT CPU client.
    pub fn new(manifest: ArtifactManifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            executables: HashMap::new(),
            resident: HashMap::new(),
            stats: ExecStats::default(),
        })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Compile (once) the executable for `name` at `size`.
    fn ensure_compiled(&mut self, name: &str, size: usize) -> Result<()> {
        let key = (name.to_string(), size);
        if !self.executables.contains_key(&key) {
            let meta = self.manifest.get(name, size)?;
            let proto = xla::HloModuleProto::from_text_file(&meta.file)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.stats.compile_count += 1;
            self.executables.insert(key, exe);
        }
        Ok(())
    }

    /// Eagerly compile a set of artifacts (start-up, off the hot path).
    pub fn preload(&mut self, names: &[&str], size: usize) -> Result<()> {
        for n in names {
            self.ensure_compiled(n, size)?;
        }
        Ok(())
    }

    /// Upload a host value; counts the transfer.  (The paper's *upload* phase.)
    fn upload(&mut self, v: &Value) -> Result<xla::PjRtBuffer> {
        let buf = match v {
            Value::Tensor(t) => {
                self.client
                    .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)?
            }
            Value::Scalar(s) => self
                .client
                .buffer_from_host_buffer::<f32>(&[*s], &[], None)?,
        };
        self.stats.uploads += 1;
        self.stats.bytes_up += v.size_bytes() as u64;
        Ok(buf)
    }

    /// Execute `name@size`, leaving the result resident on the device.
    ///
    /// Returns the payload key of the resident result.  Single-output
    /// modules can later feed dependent executions without a download.
    pub fn execute_resident(
        &mut self,
        name: &str,
        size: usize,
        inputs: &[ExecInput<'_>],
    ) -> Result<PayloadKey> {
        let meta = self.manifest.get(name, size)?.clone();
        if meta.inputs.len() != inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}@{size}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            )));
        }
        // Phase 1: upload host inputs / resolve resident ones.
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<(bool, usize, PayloadKey)> = Vec::new(); // (is_owned, idx, key)
        for inp in inputs {
            match inp {
                ExecInput::Host(v) => {
                    owned.push(self.upload(v)?);
                    order.push((true, owned.len() - 1, 0));
                }
                ExecInput::Resident(k) => {
                    let r = self
                        .resident
                        .get(k)
                        .ok_or_else(|| Error::Runtime(format!("payload {k} not resident")))?;
                    if r.n_outputs != 1 {
                        return Err(Error::Runtime(format!(
                            "payload {k} is a {}-tuple; only single-output results are reusable",
                            r.n_outputs
                        )));
                    }
                    self.stats.cache_hits += 1;
                    order.push((false, 0, *k));
                }
            }
        }
        // Phase 2: process.
        let n_outputs = meta.outputs.len();
        let out_bytes: usize = meta.outputs.iter().map(|o| o.num_elements() * 4).sum();
        self.ensure_compiled(name, size)?;
        let exe = &self.executables[&(name.to_string(), size)];
        let arg_refs: Vec<&xla::PjRtBuffer> = order
            .iter()
            .map(|(is_owned, idx, key)| {
                if *is_owned {
                    &owned[*idx]
                } else {
                    &self.resident[key].buffer
                }
            })
            .collect();
        let mut results = exe.execute_b(&arg_refs)?;
        let buffer = results
            .pop()
            .and_then(|mut v| if v.is_empty() { None } else { Some(v.remove(0)) })
            .ok_or_else(|| Error::Runtime(format!("{name}@{size}: empty result")))?;
        self.stats.executions += 1;
        let key = fresh_key();
        self.resident
            .insert(key, Resident { buffer, n_outputs, bytes: out_bytes });
        Ok(key)
    }

    /// Download a resident result to host values.  (The *download* phase.)
    pub fn download(&mut self, key: PayloadKey) -> Result<Vec<Value>> {
        let r = self
            .resident
            .get(&key)
            .ok_or_else(|| Error::Runtime(format!("payload {key} not resident")))?;
        let lit = r.buffer.to_literal_sync()?;
        self.stats.downloads += 1;
        self.stats.bytes_down += r.bytes as u64;
        let parts = if r.n_outputs == 1 {
            vec![lit]
        } else {
            let mut l = lit;
            l.decompose_tuple()?
        };
        parts
            .iter()
            .map(|l| HostTensor::from_literal(l).map(Value::Tensor))
            .collect()
    }

    /// Whether a payload is still resident (DL scheduling asks this).
    pub fn is_resident(&self, key: PayloadKey) -> bool {
        self.resident.contains_key(&key)
    }

    /// Drop a resident payload (frees device memory).
    pub fn evict(&mut self, key: PayloadKey) {
        self.resident.remove(&key);
    }

    /// Drop everything resident (end of a stage instance).
    pub fn evict_all(&mut self) {
        self.resident.clear();
    }

    /// Number of resident payloads (tests / metrics).
    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    /// Convenience: upload -> execute -> download in one go.
    pub fn run(&mut self, name: &str, size: usize, inputs: &[Value]) -> Result<Vec<Value>> {
        let refs: Vec<ExecInput<'_>> = inputs.iter().map(ExecInput::Host).collect();
        let key = self.execute_resident(name, size, &refs)?;
        let out = self.download(key)?;
        self.evict(key);
        Ok(out)
    }
}
