//! Performance-profile calibration: measured, host-specific cost models
//! feeding PATS and the simulator (ROADMAP: "per-registry runtime profile
//! calibration").
//!
//! The paper's PATS scheduler (§IV-B) and data-locality rule (§IV-C) rank
//! tasks by *estimated* GPU-vs-CPU speedup and transfer impact.  The seed
//! shipped those estimates as a static copy of the Fig. 7 table baked into
//! every [`OpSpec`](crate::dataflow::OpSpec).  This module replaces that
//! constant with a live signal:
//!
//! * **offline** — [`calibrate_workflows`] microbenchmarks every op of a
//!   workflow set on synthetic chunks, on each device kind that can
//!   actually execute it (CPU member always; accelerator member when the
//!   artifact compiles on this host), and produces a versioned
//!   [`ProfileStore`] that serialises to `profiles.json`;
//! * **online** — the Worker Resource Manager records every task
//!   completion into a [`SharedProfiles`] and folds it into per-(op,
//!   device) EWMA estimates, so queue ordering tracks the real host as the
//!   run progresses;
//! * **one store, three consumers** — `OpRegistry::apply_profiles`, the
//!   WRM's ready-task estimates and `SimWorkflow::from_workflow_profiled`
//!   all read the same [`ProfileStore`]; ops without measurements fall
//!   back to the static Fig. 7 defaults, so partial calibration degrades
//!   gracefully.

use crate::config::json::Json;
use crate::dataflow::{StageInput, StageKind, Workflow};
use crate::metrics::DeviceKind;
use crate::runtime::pjrt::DeviceExecutor;
use crate::runtime::Value;
use crate::{Error, Result};
use std::collections::BTreeMap;
use crate::runtime::sync::{self, Mutex};
use std::time::{Duration, Instant};

/// Format version written to / required from `profiles.json`.
pub const PROFILE_FORMAT_VERSION: u64 = 1;

/// Default EWMA smoothing factor for online updates.
pub const DEFAULT_ALPHA: f64 = 0.2;

/// Pseudo-op name the offline pass records per-chunk read cost under
/// (source read plus the configured `--read-latency-ms` shared-FS
/// stand-in).  `htap sim --profiles` calibrates its tile-I/O base from it.
pub const CHUNK_READ_OP: &str = "chunk_read";

/// Exponentially-weighted running estimate of one (op, device) execution
/// time, in milliseconds.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DeviceEstimate {
    pub mean_ms: f64,
    /// EW variance of the samples (dispersion diagnostic; the paper's
    /// "data-dependent performance variability", §IV-B).
    pub var_ms: f64,
    pub samples: u64,
}

impl DeviceEstimate {
    fn fold(&mut self, x_ms: f64, alpha: f64) {
        if self.samples == 0 {
            self.mean_ms = x_ms;
            self.var_ms = 0.0;
        } else {
            let delta = x_ms - self.mean_ms;
            self.mean_ms += alpha * delta;
            self.var_ms = (1.0 - alpha) * (self.var_ms + alpha * delta * delta);
        }
        self.samples += 1;
    }
}

/// Calibration record for one logical operation (registry op name).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OpCalibration {
    pub cpu: Option<DeviceEstimate>,
    pub gpu: Option<DeviceEstimate>,
    /// Measured fraction of accelerator time spent moving data, when the
    /// host could observe it (None -> fall back to the static profile).
    pub transfer_impact: Option<f32>,
}

impl OpCalibration {
    /// Measured GPU-vs-CPU speedup; None until both sides have samples.
    pub fn speedup(&self) -> Option<f32> {
        match (&self.cpu, &self.gpu) {
            (Some(c), Some(g)) if c.samples > 0 && g.samples > 0 && g.mean_ms > 0.0 => {
                Some((c.mean_ms / g.mean_ms) as f32)
            }
            _ => None,
        }
    }
}

/// A measured estimate handed to schedulers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Estimate {
    pub speedup: f32,
    /// None -> the caller keeps its static transfer-impact value.
    pub transfer_impact: Option<f32>,
}

/// Versioned, serialisable store of per-op performance calibrations.
///
/// Keys are *registry op names* (`OpDef::op`), so one store covers every
/// workflow built over a registry.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileStore {
    /// Tile edge the offline pass measured at (0 = online-only store).
    pub tile_size: usize,
    /// EWMA smoothing factor used by `record`.
    pub alpha: f64,
    ops: BTreeMap<String, OpCalibration>,
}

impl ProfileStore {
    pub fn new(tile_size: usize) -> Self {
        ProfileStore { tile_size, alpha: DEFAULT_ALPHA, ops: BTreeMap::new() }
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        self.alpha = alpha.clamp(0.0, 1.0);
        self
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn op_names(&self) -> impl Iterator<Item = &str> {
        self.ops.keys().map(|s| s.as_str())
    }

    pub fn get(&self, op: &str) -> Option<&OpCalibration> {
        self.ops.get(op)
    }

    /// Fold one measured execution into the (op, device) EWMA.
    pub fn record(&mut self, op: &str, device: DeviceKind, elapsed: Duration) {
        let alpha = self.alpha;
        let cal = self.ops.entry(op.to_string()).or_default();
        let est = match device {
            DeviceKind::Cpu => cal.cpu.get_or_insert_with(DeviceEstimate::default),
            DeviceKind::Gpu => cal.gpu.get_or_insert_with(DeviceEstimate::default),
        };
        est.fold(elapsed.as_secs_f64() * 1e3, alpha);
    }

    /// Record a measured transfer-impact fraction for an op.
    pub fn record_transfer_impact(&mut self, op: &str, ti: f32) {
        let cal = self.ops.entry(op.to_string()).or_default();
        cal.transfer_impact = Some(ti.clamp(0.0, 1.0));
    }

    /// Measured mean CPU milliseconds for one execution of `op`.
    pub fn cpu_ms(&self, op: &str) -> Option<f64> {
        self.ops.get(op).and_then(|c| c.cpu).filter(|e| e.samples > 0).map(|e| e.mean_ms)
    }

    /// Measured mean accelerator milliseconds for one execution of `op`.
    pub fn gpu_ms(&self, op: &str) -> Option<f64> {
        self.ops.get(op).and_then(|c| c.gpu).filter(|e| e.samples > 0).map(|e| e.mean_ms)
    }

    /// Measured speedup of `op`, when both device kinds have samples.
    pub fn speedup(&self, op: &str) -> Option<f32> {
        self.ops.get(op).and_then(|c| c.speedup())
    }

    /// The estimate PATS/DL should use for `op`; None -> static fallback.
    pub fn estimate(&self, op: &str) -> Option<Estimate> {
        let cal = self.ops.get(op)?;
        Some(Estimate { speedup: cal.speedup()?, transfer_impact: cal.transfer_impact })
    }

    // -- serialisation ------------------------------------------------------

    pub fn to_json(&self) -> Json {
        fn est_json(e: &DeviceEstimate) -> Json {
            let mut m = BTreeMap::new();
            m.insert("mean_ms".to_string(), Json::Num(e.mean_ms));
            m.insert("var_ms".to_string(), Json::Num(e.var_ms));
            m.insert("samples".to_string(), Json::Num(e.samples as f64));
            Json::Obj(m)
        }
        let mut ops = BTreeMap::new();
        for (name, cal) in &self.ops {
            let mut m = BTreeMap::new();
            if let Some(c) = &cal.cpu {
                m.insert("cpu".to_string(), est_json(c));
            }
            if let Some(g) = &cal.gpu {
                m.insert("gpu".to_string(), est_json(g));
            }
            if let Some(ti) = cal.transfer_impact {
                m.insert("transfer_impact".to_string(), Json::Num(ti as f64));
            }
            ops.insert(name.clone(), Json::Obj(m));
        }
        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(PROFILE_FORMAT_VERSION as f64));
        root.insert("tile_size".to_string(), Json::Num(self.tile_size as f64));
        root.insert("alpha".to_string(), Json::Num(self.alpha));
        root.insert("ops".to_string(), Json::Obj(ops));
        Json::Obj(root)
    }

    pub fn from_json(root: &Json) -> Result<Self> {
        let version = root
            .field("version")?
            .as_f64()
            .ok_or_else(|| Error::Config("profiles: 'version' must be a number".into()))?
            as u64;
        if version != PROFILE_FORMAT_VERSION {
            return Err(Error::Config(format!(
                "profiles: format version {version} unsupported (this build reads \
                 {PROFILE_FORMAT_VERSION}); re-run `htap calibrate`"
            )));
        }
        fn est(j: &Json, ctx: &str) -> Result<DeviceEstimate> {
            let num = |k: &str| -> Result<f64> {
                j.field(k)?
                    .as_f64()
                    .ok_or_else(|| Error::Config(format!("profiles: {ctx}.{k} must be a number")))
            };
            Ok(DeviceEstimate {
                mean_ms: num("mean_ms")?,
                var_ms: num("var_ms")?,
                samples: num("samples")? as u64,
            })
        }
        let mut store = ProfileStore::new(
            root.field("tile_size")?.as_usize().unwrap_or(0),
        );
        if let Ok(a) = root.field("alpha") {
            store.alpha = a.as_f64().unwrap_or(DEFAULT_ALPHA).clamp(0.0, 1.0);
        }
        let ops = root
            .field("ops")?
            .as_obj()
            .ok_or_else(|| Error::Config("profiles: 'ops' must be an object".into()))?;
        for (name, oj) in ops {
            let mut cal = OpCalibration::default();
            if let Some(obj) = oj.as_obj() {
                if obj.contains_key("cpu") {
                    cal.cpu = Some(est(oj.field("cpu")?, name)?);
                }
                if obj.contains_key("gpu") {
                    cal.gpu = Some(est(oj.field("gpu")?, name)?);
                }
                if let Some(ti) = obj.get("transfer_impact").and_then(|v| v.as_f64()) {
                    cal.transfer_impact = Some(ti as f32);
                }
            }
            store.ops.insert(name.clone(), cal);
        }
        Ok(store)
    }

    /// Write `profiles.json`.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| Error::Config(format!("cannot write profiles to '{path}': {e}")))
    }

    /// Load `profiles.json` (version-checked).
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read profiles from '{path}': {e}")))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Human-readable summary (CLI output).
    pub fn summary_table(&self) -> String {
        let mut out = format!(
            "{:<18} {:>10} {:>10} {:>9} {:>8}\n",
            "operation", "CPU (ms)", "GPU (ms)", "speedup", "samples"
        );
        for (name, cal) in &self.ops {
            let fmt_ms = |e: &Option<DeviceEstimate>| match e {
                Some(e) if e.samples > 0 => format!("{:.3}", e.mean_ms),
                _ => "-".to_string(),
            };
            let speed = match cal.speedup() {
                Some(s) => format!("{s:.2}x"),
                None => "-".to_string(),
            };
            let samples = cal.cpu.map(|e| e.samples).unwrap_or(0)
                + cal.gpu.map(|e| e.samples).unwrap_or(0);
            out.push_str(&format!(
                "{:<18} {:>10} {:>10} {:>9} {:>8}\n",
                name,
                fmt_ms(&cal.cpu),
                fmt_ms(&cal.gpu),
                speed,
                samples
            ));
        }
        out
    }
}

/// Thread-safe wrapper the WRM's device threads record completions into.
///
/// Push-time estimates come from here when an op has measurements; the
/// static Fig. 7 profile carried by the `OpDef` is the fallback, so an
/// empty store reproduces the seed behaviour exactly.
#[derive(Debug)]
pub struct SharedProfiles {
    inner: Mutex<ProfileStore>,
}

impl SharedProfiles {
    /// An empty online-only store (static estimates until samples arrive).
    pub fn fresh() -> std::sync::Arc<Self> {
        Self::from_store(ProfileStore::new(0))
    }

    /// Seed the online store with offline measurements (`--profiles`).
    pub fn from_store(store: ProfileStore) -> std::sync::Arc<Self> {
        std::sync::Arc::new(SharedProfiles { inner: Mutex::new(store) })
    }

    /// Fold a completed task's execution time into the EWMA estimates.
    pub fn record(&self, op: &str, device: DeviceKind, elapsed: Duration) {
        // EWMA bookkeeping is best-effort: recover the guard on poisoning
        sync::lock_clean(&self.inner).record(op, device, elapsed);
    }

    /// Fold a measured *end-to-end* accelerator execution (upload +
    /// process + download).  Because the sample already contains the
    /// transfer time, the measured transfer impact is pinned to 0.0 —
    /// otherwise the DL rule would discount the (already
    /// transfer-inclusive) measured speedup by the static Fig. 7
    /// transfer impact a second time.
    pub fn record_accelerator(&self, op: &str, elapsed: Duration) {
        let mut inner = sync::lock_clean(&self.inner);
        inner.record(op, DeviceKind::Gpu, elapsed);
        inner.record_transfer_impact(op, 0.0);
    }

    /// Current measured estimate for an op (None -> static fallback).
    pub fn estimate(&self, op: &str) -> Option<Estimate> {
        sync::lock_clean(&self.inner).estimate(op)
    }

    /// Clone the current store (for saving back to `profiles.json`).
    pub fn snapshot(&self) -> ProfileStore {
        sync::lock_clean(&self.inner).clone()
    }
}

/// Offline calibration parameters.
#[derive(Debug, Clone)]
pub struct CalibrationConfig {
    /// Tile edge of the synthetic chunks.
    pub tile_size: usize,
    /// Distinct chunks per workflow (captures data-dependent variability).
    pub n_chunks: usize,
    /// Measured repetitions per (op, chunk).
    pub reps: usize,
    /// Unmeasured warmup repetitions per chunk.
    pub warmup: usize,
    /// Simulated shared-FS latency folded into the chunk-read measurement
    /// (`--read-latency-ms`); recorded under [`CHUNK_READ_OP`].
    pub read_latency_ms: u64,
    pub seed: u64,
    pub alpha: f64,
}

impl Default for CalibrationConfig {
    fn default() -> Self {
        CalibrationConfig {
            tile_size: 64,
            n_chunks: 4,
            reps: 3,
            warmup: 1,
            read_latency_ms: 0,
            seed: 42,
            alpha: DEFAULT_ALPHA,
        }
    }
}

impl CalibrationConfig {
    /// Cheap smoke-level pass (CI's `htap calibrate --quick`).
    pub fn quick() -> Self {
        CalibrationConfig { tile_size: 32, n_chunks: 2, reps: 1, warmup: 0, ..Self::default() }
    }
}

/// Microbenchmark every op of `workflow` on the given per-chunk inputs and
/// fold the timings into `store`.
///
/// PerChunk stages execute serially per chunk, timing each fine-grain op's
/// CPU member individually (inputs are always valid because the real
/// upstream ops produce them).  When `executor` is given, ops with an
/// accelerator artifact also run through PJRT and record a GPU estimate —
/// a failed accelerator execution (e.g. the offline xla shim) simply
/// leaves the GPU side unmeasured.  Reduce stages are skipped: their
/// consume-all arity depends on the run's chunk count, so their cost is
/// captured by the online path instead.
pub fn calibrate_workflow(
    workflow: &Workflow,
    chunks: &[Vec<Value>],
    cfg: &CalibrationConfig,
    store: &mut ProfileStore,
    mut executor: Option<&mut DeviceExecutor>,
) -> Result<()> {
    // artifacts that already absorbed their one-time lazy compile/load
    // cost in a discarded execution (compile-once / execute-many)
    let mut warmed: std::collections::HashSet<String> = std::collections::HashSet::new();
    for chunk_inputs in chunks {
        // outputs of each completed stage, indexed by stage position
        let mut stage_outputs: Vec<Vec<Value>> = Vec::with_capacity(workflow.stages.len());
        for stage in &workflow.stages {
            if stage.kind != StageKind::PerChunk {
                stage_outputs.push(Vec::new());
                continue;
            }
            // assemble this stage's external inputs
            let mut inputs: Vec<Value> = Vec::new();
            for si in &stage.inputs {
                match si {
                    StageInput::Chunk => inputs.extend_from_slice(chunk_inputs),
                    StageInput::ChunkPart(k) => {
                        inputs.push(chunk_inputs.get(*k).cloned().ok_or_else(|| {
                            Error::Dataflow(format!(
                                "chunk payload has {} value(s), no part {k}",
                                chunk_inputs.len()
                            ))
                        })?)
                    }
                    StageInput::Upstream { stage: up, output } => {
                        let v = stage_outputs
                            .get(*up)
                            .and_then(|outs| outs.get(*output))
                            .cloned()
                            .ok_or_else(|| {
                                Error::Dataflow(format!(
                                    "calibrate: stage '{}' upstream ({up},{output}) missing",
                                    stage.name
                                ))
                            })?;
                        inputs.push(v);
                    }
                }
            }
            let mut produced: Vec<Vec<Value>> = Vec::with_capacity(stage.ops.len());
            for rep in 0..cfg.warmup + cfg.reps {
                produced.clear();
                for op in &stage.ops {
                    let args = crate::dataflow::gather_op_inputs(op, &inputs, &produced)?;
                    let t0 = Instant::now();
                    let outs = (op.variant.cpu)(&args)?;
                    if rep >= cfg.warmup {
                        store.record(&op.op, DeviceKind::Cpu, t0.elapsed());
                    }
                    // accelerator member, when this host can execute it
                    if let (Some(ex), Some(artifact)) =
                        (executor.as_deref_mut(), op.variant.gpu_artifact.as_deref())
                    {
                        if !artifact.starts_with("@stage:")
                            && ex.manifest().has(artifact, cfg.tile_size)
                        {
                            // the first execution of each artifact pays
                            // the lazy compile/load; always discard it so
                            // it can never dominate the EWMA (quick mode
                            // has warmup = 0)
                            if warmed.insert(artifact.to_string()) {
                                let _ = ex.run(artifact, cfg.tile_size, &args);
                            }
                            if rep >= cfg.warmup {
                                let t0 = Instant::now();
                                if ex.run(artifact, cfg.tile_size, &args).is_ok() {
                                    // `run` is end-to-end (upload +
                                    // process + download), so the sample
                                    // already contains the transfer cost:
                                    // pair it with transfer_impact 0 so
                                    // the DL rule doesn't discount twice
                                    store.record(&op.op, DeviceKind::Gpu, t0.elapsed());
                                    store.record_transfer_impact(&op.op, 0.0);
                                }
                            }
                        }
                    }
                    produced.push(outs);
                }
            }
            let outs: Vec<Value> = stage
                .outputs
                .iter()
                .map(|p| crate::dataflow::resolve_port(p, &inputs, &produced))
                .collect::<Result<Vec<_>>>()?;
            stage_outputs.push(outs);
        }
    }
    Ok(())
}

/// The `htap calibrate` pass: microbenchmark the full registered op set —
/// the WSI pipeline over `app::registry()` plus the generic cell-stats
/// workflow — on synthetic tiles, returning the populated store.
pub fn calibrate_workflows(cfg: &CalibrationConfig) -> Result<ProfileStore> {
    use crate::data::{SynthConfig, TileSynthesizer};
    let mut store = ProfileStore::new(cfg.tile_size).with_alpha(cfg.alpha);

    let synth = TileSynthesizer::new(SynthConfig::for_tile_size(cfg.tile_size, cfg.seed));
    let chunks: Vec<Vec<Value>> = (0..cfg.n_chunks)
        .map(|c| vec![Value::Tensor(synth.tissue_tile(c as u64).to_tensor())])
        .collect();

    let manifest = crate::runtime::ArtifactManifest::discover_or_empty();
    let mut executor =
        if manifest.is_empty() { None } else { DeviceExecutor::new(manifest).ok() };

    let params = crate::app::AppParams::for_tile_size(cfg.tile_size);
    let wsi = crate::app::build_workflow_with(
        std::sync::Arc::new(crate::app::registry()),
        &params,
        false,
    )?;
    calibrate_workflow(&wsi, &chunks, cfg, &mut store, executor.as_mut())?;

    let generic = crate::app::generic::cell_stats_workflow()?;
    calibrate_workflow(&generic, &chunks, cfg, &mut store, None)?;

    // per-chunk read cost under the simulated shared-FS latency
    // (--read-latency-ms), through the same source type staged runs use —
    // recorded as CHUNK_READ_OP so calibrated sims reflect transfer costs.
    // Only measured when a latency was actually configured: a 0-latency
    // synthetic read is memory-speed, and letting it into the store would
    // silently collapse the simulator's Lustre cost model.
    if cfg.read_latency_ms > 0 {
        use crate::data::staging::{ChunkSource, SynthSource};
        let src = SynthSource::new(
            SynthConfig::for_tile_size(cfg.tile_size, cfg.seed),
            cfg.n_chunks.max(1),
        )
        .with_read_latency(Duration::from_millis(cfg.read_latency_ms));
        for c in 0..src.n_chunks() as u64 {
            for rep in 0..cfg.warmup + cfg.reps {
                let t0 = Instant::now();
                let _ = src.load(c)?;
                if rep >= cfg.warmup {
                    store.record(CHUNK_READ_OP, DeviceKind::Cpu, t0.elapsed());
                }
            }
        }
    }
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: f64) -> Duration {
        Duration::from_secs_f64(v / 1e3)
    }

    #[test]
    fn ewma_tracks_recent_samples() {
        let mut s = ProfileStore::new(64).with_alpha(0.5);
        s.record("op", DeviceKind::Cpu, ms(10.0));
        assert_eq!(s.cpu_ms("op"), Some(10.0));
        s.record("op", DeviceKind::Cpu, ms(20.0));
        // mean moves half-way toward the new sample
        assert!((s.cpu_ms("op").unwrap() - 15.0).abs() < 1e-9);
        s.record("op", DeviceKind::Cpu, ms(20.0));
        assert!(s.cpu_ms("op").unwrap() > 15.0);
        assert_eq!(s.get("op").unwrap().cpu.unwrap().samples, 3);
        // variance is positive once samples disagree
        assert!(s.get("op").unwrap().cpu.unwrap().var_ms > 0.0);
    }

    #[test]
    fn speedup_requires_both_sides() {
        let mut s = ProfileStore::new(64);
        s.record("op", DeviceKind::Cpu, ms(100.0));
        assert_eq!(s.speedup("op"), None);
        assert!(s.estimate("op").is_none());
        s.record("op", DeviceKind::Gpu, ms(10.0));
        assert!((s.speedup("op").unwrap() - 10.0).abs() < 1e-4);
        let e = s.estimate("op").unwrap();
        assert!((e.speedup - 10.0).abs() < 1e-4);
        assert_eq!(e.transfer_impact, None);
        s.record_transfer_impact("op", 0.25);
        assert_eq!(s.estimate("op").unwrap().transfer_impact, Some(0.25));
    }

    #[test]
    fn json_round_trip_is_exact() {
        let mut s = ProfileStore::new(64).with_alpha(0.3);
        s.record("a", DeviceKind::Cpu, ms(3.5));
        s.record("a", DeviceKind::Cpu, ms(4.5));
        s.record("a", DeviceKind::Gpu, ms(0.7));
        s.record_transfer_impact("a", 0.125);
        s.record("b", DeviceKind::Cpu, ms(9.0));
        let j = s.to_json();
        let back = ProfileStore::from_json(&j).unwrap();
        assert_eq!(back.tile_size, 64);
        assert_eq!(back.alpha, 0.3);
        assert_eq!(back.len(), 2);
        // identical estimates after the round trip
        assert_eq!(back.cpu_ms("a"), s.cpu_ms("a"));
        assert_eq!(back.gpu_ms("a"), s.gpu_ms("a"));
        assert_eq!(back.speedup("a"), s.speedup("a"));
        assert_eq!(back.estimate("a"), s.estimate("a"));
        assert_eq!(back.cpu_ms("b"), s.cpu_ms("b"));
        assert_eq!(back.speedup("b"), None);
        assert_eq!(back, s);
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut s = ProfileStore::new(64);
        s.record("a", DeviceKind::Cpu, ms(1.0));
        let mut j = s.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".to_string(), Json::Num(99.0));
        }
        let err = ProfileStore::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn file_round_trip() {
        let mut s = ProfileStore::new(32);
        s.record("x", DeviceKind::Cpu, ms(2.0));
        s.record("x", DeviceKind::Gpu, ms(1.0));
        let path = std::env::temp_dir().join("htap_profiles_test.json");
        let path = path.to_str().unwrap();
        s.save(path).unwrap();
        let back = ProfileStore::load(path).unwrap();
        assert_eq!(back, s);
        assert!(ProfileStore::load("/definitely/not/here.json").is_err());
    }

    #[test]
    fn shared_profiles_record_and_estimate() {
        let shared = SharedProfiles::fresh();
        assert!(shared.estimate("op").is_none());
        shared.record("op", DeviceKind::Cpu, ms(50.0));
        shared.record("op", DeviceKind::Gpu, ms(5.0));
        let e = shared.estimate("op").unwrap();
        assert!((e.speedup - 10.0).abs() < 1e-4);
        let snap = shared.snapshot();
        assert_eq!(snap.get("op").unwrap().cpu.unwrap().samples, 1);
    }

    #[test]
    fn accelerator_samples_pin_transfer_impact_to_zero() {
        let shared = SharedProfiles::fresh();
        shared.record("op", DeviceKind::Cpu, ms(8.0));
        shared.record_accelerator("op", ms(4.0));
        let e = shared.estimate("op").unwrap();
        assert!((e.speedup - 2.0).abs() < 1e-4);
        // the end-to-end sample already contains the transfer cost, so the
        // DL rule must not discount it a second time
        assert_eq!(e.transfer_impact, Some(0.0));
    }

    #[test]
    fn summary_table_lists_ops() {
        let mut s = ProfileStore::new(64);
        s.record("watershed", DeviceKind::Cpu, ms(4.0));
        let t = s.summary_table();
        assert!(t.contains("watershed"));
        assert!(t.contains("4.000"));
    }

    #[test]
    fn quick_calibration_measures_every_cpu_op() {
        let store = calibrate_workflows(&CalibrationConfig::quick()).unwrap();
        // every WSI pipeline op and every generic op has a CPU estimate
        for op in [
            "hema_prep",
            "rbc_detect",
            "morph_open",
            "recon_to_nuclei",
            "fill_holes",
            "area_threshold",
            "bwlabel",
            "pre_watershed",
            "watershed",
            "feature_graph",
            "object_features",
            "haralick",
            "grayscale",
            "binarize",
            "cc_label",
            "region_stats",
        ] {
            let ms = store.cpu_ms(op);
            assert!(ms.is_some(), "no CPU estimate for {op}");
            assert!(ms.unwrap() >= 0.0);
        }
        // the reduce-stage ops are deliberately not offline-calibrated
        assert!(store.cpu_ms("mean_stats").is_none());
    }
}
