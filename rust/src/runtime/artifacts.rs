//! Artifact manifest: what `make artifacts` produced and where.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing every
//! lowered module (name, tile size, file, input/output specs).  The
//! coordinator consults the manifest to bind function variants; the
//! [`DeviceExecutor`](super::pjrt::DeviceExecutor) uses it to locate and
//! validate HLO files.

use crate::config::json::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one module input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .field("shape")?
            .as_arr()
            .ok_or_else(|| Error::Config("shape must be array".into()))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| Error::Config("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .field("dtype")?
            .as_str()
            .ok_or_else(|| Error::Config("dtype must be string".into()))?
            .to_string();
        Ok(Self { shape, dtype })
    }

    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered module (a graph specialised to one tile size).
#[derive(Debug, Clone)]
pub struct ModuleMeta {
    pub name: String,
    pub size: usize,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub tile_sizes: Vec<usize>,
    modules: BTreeMap<(String, usize), ModuleMeta>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Config(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let root = Json::parse(&text)?;
        let tile_sizes = root
            .field("tile_sizes")?
            .as_arr()
            .ok_or_else(|| Error::Config("tile_sizes must be array".into()))?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        let mut modules = BTreeMap::new();
        for m in root
            .field("modules")?
            .as_arr()
            .ok_or_else(|| Error::Config("modules must be array".into()))?
        {
            let name = m.field("name")?.as_str().unwrap_or_default().to_string();
            let size = m
                .field("size")?
                .as_usize()
                .ok_or_else(|| Error::Config("bad module size".into()))?;
            let file = dir.join(m.field("file")?.as_str().unwrap_or_default());
            let inputs = m
                .field("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = m
                .field("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            modules.insert((name.clone(), size), ModuleMeta { name, size, file, inputs, outputs });
        }
        Ok(Self { dir, tile_sizes, modules })
    }

    /// A manifest with no modules: every function variant degrades to its
    /// CPU member (pure-CPU execution).
    pub fn empty() -> Self {
        Self { dir: PathBuf::from("artifacts"), tile_sizes: Vec::new(), modules: BTreeMap::new() }
    }

    /// [`ArtifactManifest::discover`], degrading to [`ArtifactManifest::empty`]
    /// when no artifacts have been built — the coordinator then runs every
    /// operation on its CPU member.  A manifest that *exists* but fails to
    /// load (corrupt JSON, unreadable dir) is not silently ignored: a
    /// warning is printed before degrading, so a hybrid-looking run never
    /// quietly turns pure-CPU.
    pub fn discover_or_empty() -> Self {
        match Self::default_dir() {
            None => Self::empty(),
            Some(dir) => Self::load(&dir).unwrap_or_else(|e| {
                eprintln!(
                    "htap: warning: ignoring artifacts at {}: {e}; running CPU-only",
                    dir.display()
                );
                Self::empty()
            }),
        }
    }

    /// The directory `discover` would load from: `$HTAP_ARTIFACTS`, or the
    /// nearest `artifacts/manifest.json` walking up from the cwd.
    fn default_dir() -> Option<PathBuf> {
        if let Ok(dir) = std::env::var("HTAP_ARTIFACTS") {
            return Some(PathBuf::from(dir));
        }
        let mut cur = std::env::current_dir().ok()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Some(cand);
            }
            if !cur.pop() {
                return None;
            }
        }
    }

    /// Locate the default artifact directory: `$HTAP_ARTIFACTS` or
    /// `artifacts/` relative to the workspace root (walking up from cwd).
    pub fn discover() -> Result<Self> {
        match Self::default_dir() {
            Some(dir) => Self::load(dir),
            None => Err(Error::Config(
                "no artifacts/manifest.json found; run `make artifacts` or set HTAP_ARTIFACTS"
                    .into(),
            )),
        }
    }

    pub fn get(&self, name: &str, size: usize) -> Result<&ModuleMeta> {
        self.modules.get(&(name.to_string(), size)).ok_or_else(|| {
            Error::Config(format!(
                "artifact '{name}' at tile size {size} not in manifest (have sizes {:?})",
                self.tile_sizes
            ))
        })
    }

    pub fn has(&self, name: &str, size: usize) -> bool {
        self.modules.contains_key(&(name.to_string(), size))
    }

    pub fn module_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.modules.keys().map(|(n, _)| n.clone()).collect();
        names.dedup();
        names
    }

    pub fn len(&self) -> usize {
        self.modules.len()
    }

    pub fn is_empty(&self) -> bool {
        self.modules.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("htap_manifest_test");
        write_manifest(
            &dir,
            r#"{"tile_sizes": [64], "modules": [
                {"name": "morph_open", "size": 64, "file": "morph_open_64.hlo.txt",
                 "inputs": [{"shape": [64, 64], "dtype": "float32"}],
                 "outputs": [{"shape": [64, 64], "dtype": "float32"}]}]}"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.tile_sizes, vec![64]);
        assert!(m.has("morph_open", 64));
        assert!(!m.has("morph_open", 256));
        let meta = m.get("morph_open", 64).unwrap();
        assert_eq!(meta.inputs[0].shape, vec![64, 64]);
        assert_eq!(meta.inputs[0].num_elements(), 4096);
        assert!(m.get("nope", 64).is_err());
    }

    #[test]
    fn missing_dir_is_config_error() {
        let err = ArtifactManifest::load("/definitely/not/here").unwrap_err();
        assert!(matches!(err, Error::Config(_)));
    }
}
