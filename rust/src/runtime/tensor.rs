//! Host-side tensors: the payloads that flow between pipeline operations.
//!
//! All artifact I/O is f32 (labels are exact small integers stored in f32 —
//! see python/compile/model.py), so a single dense f32 tensor type plus a
//! scalar wrapper covers every stream in the application.
//!
//! Tensors are **immutable-after-construction shared buffers**: both the
//! payload and the shape live behind `Arc`s, so `HostTensor::clone` (and
//! therefore `Value::clone`) is two reference-count bumps — O(1), never a
//! byte copy.  Every hand-off in the runtime (WRM dispatch, stage-output
//! collection, staging cache, Manager routing) relies on this: a 4K×4K f32
//! tile is ~64 MB, and the paper's throughput target only holds if tiles
//! move by reference.  The one mutation door, [`HostTensor::data_mut`], is
//! copy-on-write (`Arc::make_mut`), so a writer can never scribble over a
//! buffer another consumer still reads.  See docs/perf.md.

use crate::{Error, Result};
use std::sync::Arc;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    shape: Arc<[usize]>,
    data: Arc<Vec<f32>>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::ImgProc(format!(
                "shape {:?} implies {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Self { shape: shape.into(), data: Arc::new(data) })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.into(), data: Arc::new(vec![0.0; n]) }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: Vec::new().into(), data: Arc::new(vec![v]) }
    }

    /// Whether `self` and `other` share one underlying payload buffer —
    /// i.e. one was cloned from the other without a copy.  Tests use this
    /// to pin the O(1)-clone guarantee.
    pub fn shares_buffer(&self, other: &HostTensor) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access; clones the buffer if it is shared (copy-on-write).
    pub fn data_mut(&mut self) -> &mut Vec<f32> {
        Arc::make_mut(&mut self.data)
    }

    pub fn at2(&self, y: usize, x: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[y * self.shape[1] + x]
    }

    /// Convert to an XLA literal (reshaped to this tensor's dims).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Build from an XLA literal (must be f32).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        HostTensor::new(dims, data)
    }

    /// Max absolute difference against another tensor (shape-checked).
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(Error::ImgProc(format!(
                "shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }
}

/// A value on a dataflow stream.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Tensor(HostTensor),
    Scalar(f32),
}

impl Value {
    pub fn tensor(shape: Vec<usize>, data: Vec<f32>) -> Result<Value> {
        Ok(Value::Tensor(HostTensor::new(shape, data)?))
    }

    pub fn as_tensor(&self) -> Result<&HostTensor> {
        match self {
            Value::Tensor(t) => Ok(t),
            Value::Scalar(_) => Err(Error::Dataflow("expected tensor, got scalar".into())),
        }
    }

    pub fn as_scalar(&self) -> Result<f32> {
        match self {
            Value::Scalar(s) => Ok(*s),
            Value::Tensor(t) if t.len() == 1 => Ok(t.data()[0]),
            _ => Err(Error::Dataflow("expected scalar, got tensor".into())),
        }
    }

    /// Bytes moved when this value crosses the host/device boundary.
    pub fn size_bytes(&self) -> usize {
        match self {
            Value::Tensor(t) => t.size_bytes(),
            Value::Scalar(_) => 4,
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Value::Tensor(t) => t.to_literal(),
            Value::Scalar(s) => Ok(xla::Literal::scalar(*s)),
        }
    }

    /// Whether two values are tensors sharing one payload buffer (see
    /// [`HostTensor::shares_buffer`]).  Scalars are inline; they never
    /// "share".
    pub fn shares_buffer(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Tensor(a), Value::Tensor(b)) => a.shares_buffer(b),
            _ => false,
        }
    }
}

/// Append `data` to `buf` as packed little-endian f32 bytes in one bulk
/// copy.  Shared by every tensor codec (`net::proto` frames, the `.tile` /
/// `.spill` containers) so serialisation reads straight through the shared
/// buffer — no per-element loop, no intermediate `Vec`.
pub fn f32s_to_le(buf: &mut Vec<u8>, data: &[f32]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: on a little-endian target the in-memory representation of
        // an f32 slice IS its packed LE byte encoding; f32 has no padding
        // and u8 has alignment 1, so the cast view is always valid.
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        buf.extend_from_slice(bytes);
    }
    #[cfg(not(target_endian = "little"))]
    for &f in data {
        buf.extend_from_slice(&f.to_le_bytes());
    }
}

/// Decode packed little-endian f32 bytes (inverse of [`f32s_to_le`]).
/// `bytes.len()` must be a multiple of 4; the trailing remainder of a
/// malformed slice is ignored, matching `chunks_exact`.
pub fn f32s_from_le(bytes: &[u8]) -> Vec<f32> {
    let n = bytes.len() / 4;
    #[cfg(target_endian = "little")]
    {
        let mut data: Vec<f32> = vec![0.0; n];
        // SAFETY: the destination holds exactly n initialised f32s; this is
        // a plain byte copy (unaligned source is fine), and on a
        // little-endian target those bytes are the f32 values themselves.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), data.as_mut_ptr() as *mut u8, n * 4);
        }
        data
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut data = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            // lint: allow(panic) — chunks_exact(4) guarantees a 4-byte slice
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn cow_semantics() {
        let a = HostTensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let mut b = a.clone();
        b.data_mut()[0] = 9.0;
        assert_eq!(a.data()[0], 1.0);
        assert_eq!(b.data()[0], 9.0);
    }

    #[test]
    fn at2_indexing() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.at2(0, 0), 0.0);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = HostTensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = HostTensor::new(vec![3], vec![1.0, 2.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
        let c = HostTensor::new(vec![2], vec![0.0; 2]).unwrap();
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn value_scalar_coercion() {
        let v = Value::Tensor(HostTensor::scalar(4.0));
        assert_eq!(v.as_scalar().unwrap(), 4.0);
        assert_eq!(Value::Scalar(2.0).size_bytes(), 4);
    }

    #[test]
    fn clone_shares_the_buffer() {
        // the zero-copy contract: cloning a Value bumps the Arc, it never
        // copies the payload (a 4Kx4K tile is ~64 MB — this is load-bearing)
        let a = Value::tensor(vec![256, 256], vec![1.5; 256 * 256]).unwrap();
        let b = a.clone();
        assert!(a.shares_buffer(&b), "Value::clone must not copy the tensor buffer");
        // an independent construction with equal contents does NOT share
        let c = Value::tensor(vec![256, 256], vec![1.5; 256 * 256]).unwrap();
        assert_eq!(a, c);
        assert!(!a.shares_buffer(&c));
        // copy-on-write breaks sharing instead of mutating through it
        let (Value::Tensor(t), Value::Tensor(mut u)) = (a.clone(), b.clone()) else {
            unreachable!()
        };
        u.data_mut()[0] = 9.0;
        assert!(!t.shares_buffer(&u));
        assert_eq!(t.data()[0], 1.5);
        // scalars are inline values; shares_buffer is tensor-only
        assert!(!Value::Scalar(1.0).shares_buffer(&Value::Scalar(1.0)));
    }

    #[test]
    fn f32_le_codec_round_trips() {
        let vals = vec![0.0f32, -1.5, f32::MIN_POSITIVE, 3.25e10, -0.0];
        let mut buf = vec![0xAAu8]; // pre-existing bytes must be preserved
        f32s_to_le(&mut buf, &vals);
        assert_eq!(buf.len(), 1 + vals.len() * 4);
        // byte-exact against the per-element encoding
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(&buf[1 + i * 4..1 + (i + 1) * 4], &v.to_le_bytes());
        }
        // decode from an odd offset (unaligned source) must still work
        let back = f32s_from_le(&buf[1..]);
        assert_eq!(back.len(), vals.len());
        for (a, b) in back.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "codec must be bit-exact");
        }
        assert!(f32s_from_le(&[]).is_empty());
    }
}
