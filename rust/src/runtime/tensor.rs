//! Host-side tensors: the payloads that flow between pipeline operations.
//!
//! All artifact I/O is f32 (labels are exact small integers stored in f32 —
//! see python/compile/model.py), so a single dense f32 tensor type plus a
//! scalar wrapper covers every stream in the application.

use crate::{Error, Result};
use std::sync::Arc;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    shape: Vec<usize>,
    data: Arc<Vec<f32>>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::ImgProc(format!(
                "shape {:?} implies {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Self { shape, data: Arc::new(data) })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: Arc::new(vec![0.0; n]) }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: Arc::new(vec![v]) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access; clones the buffer if it is shared (copy-on-write).
    pub fn data_mut(&mut self) -> &mut Vec<f32> {
        Arc::make_mut(&mut self.data)
    }

    pub fn at2(&self, y: usize, x: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[y * self.shape[1] + x]
    }

    /// Convert to an XLA literal (reshaped to this tensor's dims).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Build from an XLA literal (must be f32).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        HostTensor::new(dims, data)
    }

    /// Max absolute difference against another tensor (shape-checked).
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(Error::ImgProc(format!(
                "shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }
}

/// A value on a dataflow stream.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Tensor(HostTensor),
    Scalar(f32),
}

impl Value {
    pub fn tensor(shape: Vec<usize>, data: Vec<f32>) -> Result<Value> {
        Ok(Value::Tensor(HostTensor::new(shape, data)?))
    }

    pub fn as_tensor(&self) -> Result<&HostTensor> {
        match self {
            Value::Tensor(t) => Ok(t),
            Value::Scalar(_) => Err(Error::Dataflow("expected tensor, got scalar".into())),
        }
    }

    pub fn as_scalar(&self) -> Result<f32> {
        match self {
            Value::Scalar(s) => Ok(*s),
            Value::Tensor(t) if t.len() == 1 => Ok(t.data()[0]),
            _ => Err(Error::Dataflow("expected scalar, got tensor".into())),
        }
    }

    /// Bytes moved when this value crosses the host/device boundary.
    pub fn size_bytes(&self) -> usize {
        match self {
            Value::Tensor(t) => t.size_bytes(),
            Value::Scalar(_) => 4,
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Value::Tensor(t) => t.to_literal(),
            Value::Scalar(s) => Ok(xla::Literal::scalar(*s)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn cow_semantics() {
        let a = HostTensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let mut b = a.clone();
        b.data_mut()[0] = 9.0;
        assert_eq!(a.data()[0], 1.0);
        assert_eq!(b.data()[0], 9.0);
    }

    #[test]
    fn at2_indexing() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.at2(0, 0), 0.0);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = HostTensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = HostTensor::new(vec![3], vec![1.0, 2.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
        let c = HostTensor::new(vec![2], vec![0.0; 2]).unwrap();
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn value_scalar_coercion() {
        let v = Value::Tensor(HostTensor::scalar(4.0));
        assert_eq!(v.as_scalar().unwrap(), 4.0);
        assert_eq!(Value::Scalar(2.0).size_bytes(), 4);
    }
}
