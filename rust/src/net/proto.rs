//! Length-prefixed binary framing for the Manager/Worker protocol.
//!
//! Frame layout: `u32 LE length` + payload.  Payload starts with a
//! one-byte protocol version ([`PROTO_VERSION`]) and a one-byte message
//! tag; tensors are shipped as rank + dims + raw f32 LE bytes (a 4Kx4K
//! tile is ~192 MB as JSON but 64 MB raw — binary matters here).
//!
//! v2 extended the demand-driven handshake for the data-staging layer:
//! `Request` carries the worker's identity plus its staged/evicted chunk
//! deltas, and `Assign` carries per-assignment deferred-chunk/locality
//! flags plus the Manager's prefetch hints.  v3 added the storage-tier
//! fields: `Request` reports the chunks demoted to the worker's local-disk
//! spill tier, and `Assign` carries a per-assignment replica flag plus the
//! Manager's replicate hints (chunks a steal left multi-homed).  v4 added
//! the elastic-membership messages: `Hello` (worker identity + the lease
//! term it promises to heartbeat within), `Heartbeat` (lease renewal) and
//! `Goodbye` (clean departure, distinguishing a drained worker from a
//! crashed one).  v5 added the multi-tenant service surface: `Submit`
//! (a tenant's workflow JSON + priority), `JobStatus`/`JobReport` (job
//! lifecycle queries), `CancelJob`, `GetJob`/`JobSpec` (workers fetch
//! the workflow of a job they were assigned), and `Idle` (the
//! long-running service has nothing assignable *right now* — poll
//! again; an empty `Assign` still means shut down).  v6 added the
//! observability surface: `TraceBatch` (a worker ships its drained trace
//! ring, piggybacked on the heartbeat cadence) and `StatsQuery` /
//! `StatsReport` (the `htap top` live per-tenant/per-worker utilization
//! poll).  A version mismatch is a decode error, not a silent misparse.

use crate::coordinator::manager::Assignment;
use crate::obs::{EventKind, Name, TraceEvent, UtilRow};
use crate::service::JobSummary;
use crate::runtime::tensor::{f32s_from_le, f32s_to_le};
use crate::runtime::{HostTensor, Value};
use crate::{Error, Result};
use std::io::{Read, Write};

/// Maximum accepted frame (guards against corrupt length prefixes).
const MAX_FRAME: u32 = 1 << 30;

/// Wire-format version; every payload starts with it.  Bumped to 2 when
/// the staging fields (worker identity, staged-chunk hints, deferred-chunk
/// and locality flags, prefetch hints) were added, to 3 for the
/// storage-tier fields (demoted deltas, replica flags, replicate hints),
/// to 4 for the elastic-membership messages (Hello / Heartbeat /
/// Goodbye with a lease term), to 5 for the multi-tenant service
/// messages (Submit / JobStatus / JobReport / CancelJob / GetJob /
/// JobSpec / Idle), and to 6 for the observability messages
/// (TraceBatch / StatsQuery / StatsReport).
pub const PROTO_VERSION: u8 = 6;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker -> Manager: give me up to `capacity` stage instances.
    /// `worker` is the requester's stable identity (0 = anonymous);
    /// `staged_add`/`staged_drop` are the chunks it staged/evicted since
    /// its last request and `demoted` those it moved to its local-disk
    /// spill tier (still staged, a tier down); `prefetch_budget` asks for
    /// that many upcoming chunk ids as prefetch hints.
    Request {
        capacity: u32,
        worker: u64,
        prefetch_budget: u32,
        staged_add: Vec<u64>,
        staged_drop: Vec<u64>,
        demoted: Vec<u64>,
    },
    /// Manager -> Worker: assignments (empty = workflow complete) plus
    /// chunk ids the worker should prefetch into its staging cache and
    /// replicate hints (stolen chunks now multi-homed, worth keeping warm).
    Assign { assignments: Vec<Assignment>, prefetch: Vec<u64>, replicate: Vec<u64> },
    /// Worker -> Manager: stage instance finished.
    Complete { instance: u64, outputs: Vec<Value> },
    /// Worker -> Manager: fatal worker error.
    Fail { msg: String },
    /// Worker -> Manager (v4): join the membership.  `lease_ms` is the
    /// lease term the worker promises to renew within — if the manager
    /// hears nothing (no heartbeat, request or completion) for a full
    /// term, the worker is presumed dead: its catalog entries are purged
    /// and its in-flight assignments re-issued.  `lease_ms == 0` opts out
    /// of lease tracking (connection-drop detection still applies).
    Hello { worker: u64, lease_ms: u64 },
    /// Worker -> Manager (v4): lease renewal, sent on the completion
    /// channel between completions so an idle-but-alive worker is never
    /// presumed dead.
    Heartbeat { worker: u64 },
    /// Worker -> Manager (v4): clean departure — the worker drained its
    /// in-flight work and is leaving; purge immediately, log nothing.
    Goodbye { worker: u64 },
    /// Service -> Worker (v5): nothing assignable *right now*, but the
    /// service is long-running and more jobs may arrive — poll again.
    /// Distinct from an empty `Assign`, which still means shut down.
    Idle,
    /// Client -> Service (v5): submit a workflow for execution.  `tenant`
    /// names the submitting tenant (fair-share + quota identity);
    /// `priority` is the tenant's fair-share weight (0 = default 1).
    /// Replied with a one-entry `JobReport` (accepted) or `Fail`
    /// (rejected by admission control / invalid workflow).
    Submit { tenant: String, workflow_json: String, priority: u32 },
    /// Client -> Service (v5): report job `job`'s lifecycle state, or all
    /// jobs when `job == 0`.  Replied with `JobReport`.
    JobStatus { job: u64 },
    /// Client -> Service (v5): cancel a queued or running job.  Replied
    /// with a one-entry `JobReport` (now Cancelled) or `Fail`.
    CancelJob { job: u64 },
    /// Service -> Client (v5): job lifecycle summaries.
    JobReport { jobs: Vec<JobSummary> },
    /// Worker -> Service (v5): fetch the workflow of a job this worker was
    /// assigned work from (service mode multiplexes many workflows over
    /// one pool; assignments carry only the job-tagged instance id).
    GetJob { job: u64 },
    /// Service -> Worker (v5): reply to `GetJob` — the tenant (staging
    /// quota identity) and workflow JSON to compile against the registry.
    JobSpec { job: u64, tenant: String, workflow_json: String },
    /// Worker -> Manager (v6): a drained batch of trace events, shipped
    /// on the completion channel at the heartbeat cadence (plus one final
    /// drain at exit).  Fire-and-forget: the manager merges the batch
    /// into its collector, no reply.
    TraceBatch { worker: u64, events: Vec<TraceEvent> },
    /// Client -> Manager/Service (v6): ask for the live per-worker
    /// utilization rollups (`htap top`).  Replied with `StatsReport`.
    StatsQuery,
    /// Manager/Service -> Client (v6): reply to `StatsQuery` — one row
    /// per (worker, job) with tenant attribution joined in by the
    /// service layer.
    StatsReport { rows: Vec<UtilRow> },
}

const TAG_REQUEST: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_COMPLETE: u8 = 3;
const TAG_FAIL: u8 = 4;
const TAG_HELLO: u8 = 5;
const TAG_HEARTBEAT: u8 = 6;
const TAG_GOODBYE: u8 = 7;
const TAG_IDLE: u8 = 8;
const TAG_SUBMIT: u8 = 9;
const TAG_JOB_STATUS: u8 = 10;
const TAG_CANCEL_JOB: u8 = 11;
const TAG_JOB_REPORT: u8 = 12;
const TAG_GET_JOB: u8 = 13;
const TAG_JOB_SPEC: u8 = 14;
const TAG_TRACE_BATCH: u8 = 15;
const TAG_STATS_QUERY: u8 = 16;
const TAG_STATS_REPORT: u8 = 17;

/// Assignment flag bits (v2; FLAG_REPLICA since v3).
const FLAG_NEEDS_CHUNK: u8 = 1;
const FLAG_LOCALITY: u8 = 2;
const FLAG_REPLICA: u8 = 4;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_value(buf: &mut Vec<u8>, v: &Value) {
    match v {
        Value::Scalar(s) => {
            buf.push(0);
            buf.extend_from_slice(&s.to_le_bytes());
        }
        Value::Tensor(t) => {
            buf.push(1);
            buf.reserve(4 + t.shape().len() * 8 + t.size_bytes());
            put_u32(buf, t.shape().len() as u32);
            for &d in t.shape() {
                put_u64(buf, d as u64);
            }
            // one bulk copy straight from the tensor's shared buffer —
            // this is the wire-side half of the zero-copy datapath
            f32s_to_le(buf, t.data());
        }
    }
}

fn put_values(buf: &mut Vec<u8>, vals: &[Value]) {
    put_u32(buf, vals.len() as u32);
    for v in vals {
        put_value(buf, v);
    }
}

fn put_ids(buf: &mut Vec<u8>, ids: &[u64]) {
    put_u32(buf, ids.len() as u32);
    for &id in ids {
        put_u64(buf, id);
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Trace-event wire layout (v6): fixed numeric fields then a one-byte
/// length-prefixed name (names are capped at [`crate::obs::NAME_CAP`]
/// bytes, so a u8 length suffices).  51 bytes minimum per event — the
/// `count()` bound for `TraceBatch`.
const MIN_EVENT_BYTES: usize = 51;

fn put_event(buf: &mut Vec<u8>, ev: &TraceEvent) {
    put_u64(buf, ev.ts_us);
    put_u64(buf, ev.dur_us);
    buf.push(ev.kind as u8);
    buf.push(ev.device);
    put_u64(buf, ev.worker);
    put_u32(buf, ev.lane);
    put_u64(buf, ev.job);
    put_u32(buf, ev.stage);
    put_u64(buf, ev.chunk);
    buf.push(ev.name.as_bytes().len() as u8);
    buf.extend_from_slice(ev.name.as_bytes());
}

/// Utilization-row wire layout (v6): worker + job + tenant string +
/// ops + busy_us.  36 bytes minimum per row — the `count()` bound for
/// `StatsReport`.
const MIN_UTIL_ROW_BYTES: usize = 36;

fn put_util_row(buf: &mut Vec<u8>, r: &UtilRow) {
    put_u64(buf, r.worker);
    put_u64(buf, r.job);
    put_str(buf, &r.tenant);
    put_u64(buf, r.ops);
    put_u64(buf, r.busy_us);
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked: a corrupt length near usize::MAX must be a decode
        // error, not a wrapping-add panic/misread
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.data.len())
            .ok_or_else(|| Error::Net("truncated frame".into()))?;
        let s = &self.data[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    /// Read a count prefix, bounding it by the bytes actually left in the
    /// frame (`min_elem_bytes` per element).  A hostile count must fail
    /// here — *before* any `Vec::with_capacity`-style preallocation — or a
    /// 6-byte frame could claim 2^32 elements and force a multi-gigabyte
    /// allocation ahead of the truncation error.
    fn count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_bytes) > self.remaining() {
            return Err(Error::Net(format!(
                "count {n} exceeds frame ({} bytes left)",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn u32(&mut self) -> Result<u32> {
        // lint: allow(panic) — take() guarantees a 4-byte slice
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        // lint: allow(panic) — take() guarantees an 8-byte slice
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        // lint: allow(panic) — take() guarantees a 4-byte slice
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn value(&mut self) -> Result<Value> {
        match self.u8()? {
            0 => Ok(Value::Scalar(self.f32()?)),
            1 => {
                let rank = self.u32()? as usize;
                if rank > 8 {
                    return Err(Error::Net(format!("tensor rank {rank} too large")));
                }
                let mut dims = Vec::with_capacity(rank);
                for _ in 0..rank {
                    dims.push(self.u64()? as usize);
                }
                // checked element count (same rule as the on-disk codec's
                // decode_tensor): wrapped products must be decode errors,
                // never a panic or a shape/data-inconsistent tensor
                let n = dims
                    .iter()
                    .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                    .and_then(|n| n.checked_mul(4))
                    .ok_or_else(|| Error::Net("tensor dims overflow".into()))?;
                let bytes = self.take(n)?;
                Ok(Value::Tensor(HostTensor::new(dims, f32s_from_le(bytes))?))
            }
            t => Err(Error::Net(format!("bad value tag {t}"))),
        }
    }

    fn values(&mut self) -> Result<Vec<Value>> {
        let n = self.count(5)?; // tag byte + f32 scalar at minimum
        (0..n).map(|_| self.value()).collect()
    }

    fn ids(&mut self) -> Result<Vec<u64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| Error::Net("bad utf8".into()))
    }

    fn event(&mut self) -> Result<TraceEvent> {
        let ts_us = self.u64()?;
        let dur_us = self.u64()?;
        let kind_byte = self.u8()?;
        let kind = EventKind::from_u8(kind_byte)
            .ok_or_else(|| Error::Net(format!("bad trace event kind {kind_byte}")))?;
        let device = self.u8()?;
        let worker = self.u64()?;
        let lane = self.u32()?;
        let job = self.u64()?;
        let stage = self.u32()?;
        let chunk = self.u64()?;
        let name_len = self.u8()? as usize;
        let name = Name::from_bytes(self.take(name_len)?)
            .ok_or_else(|| Error::Net("bad trace event name".into()))?;
        Ok(TraceEvent { ts_us, dur_us, kind, device, worker, lane, job, stage, chunk, name })
    }

    fn util_row(&mut self) -> Result<UtilRow> {
        let worker = self.u64()?;
        let job = self.u64()?;
        let tenant = self.string()?;
        let ops = self.u64()?;
        let busy_us = self.u64()?;
        Ok(UtilRow { worker, job, tenant, ops, busy_us })
    }
}

/// Encode a message (without the length prefix).
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_into(msg, &mut buf);
    buf
}

/// Encode a message into a caller-owned buffer (cleared first, capacity
/// retained).  Connection loops reuse one scratch buffer across frames so
/// steady-state encoding allocates nothing — see [`write_message_buf`].
pub fn encode_into(msg: &Message, buf: &mut Vec<u8>) {
    buf.clear();
    buf.push(PROTO_VERSION);
    match msg {
        Message::Request {
            capacity,
            worker,
            prefetch_budget,
            staged_add,
            staged_drop,
            demoted,
        } => {
            buf.push(TAG_REQUEST);
            put_u32(buf, *capacity);
            put_u64(buf, *worker);
            put_u32(buf, *prefetch_budget);
            put_ids(buf, staged_add);
            put_ids(buf, staged_drop);
            put_ids(buf, demoted);
        }
        Message::Assign { assignments, prefetch, replicate } => {
            buf.push(TAG_ASSIGN);
            put_u32(buf, assignments.len() as u32);
            for a in assignments {
                put_u64(buf, a.instance_id);
                put_u32(buf, a.stage_idx as u32);
                put_u64(buf, a.chunk);
                let mut flags = 0u8;
                if a.needs_chunk {
                    flags |= FLAG_NEEDS_CHUNK;
                }
                if a.locality {
                    flags |= FLAG_LOCALITY;
                }
                if a.replica {
                    flags |= FLAG_REPLICA;
                }
                buf.push(flags);
                put_values(buf, &a.inputs);
            }
            put_ids(buf, prefetch);
            put_ids(buf, replicate);
        }
        Message::Complete { instance, outputs } => {
            buf.push(TAG_COMPLETE);
            put_u64(buf, *instance);
            put_values(buf, outputs);
        }
        Message::Fail { msg } => {
            buf.push(TAG_FAIL);
            put_u32(buf, msg.len() as u32);
            buf.extend_from_slice(msg.as_bytes());
        }
        Message::Hello { worker, lease_ms } => {
            buf.push(TAG_HELLO);
            put_u64(buf, *worker);
            put_u64(buf, *lease_ms);
        }
        Message::Heartbeat { worker } => {
            buf.push(TAG_HEARTBEAT);
            put_u64(buf, *worker);
        }
        Message::Goodbye { worker } => {
            buf.push(TAG_GOODBYE);
            put_u64(buf, *worker);
        }
        Message::Idle => {
            buf.push(TAG_IDLE);
        }
        Message::Submit { tenant, workflow_json, priority } => {
            buf.push(TAG_SUBMIT);
            put_str(buf, tenant);
            put_str(buf, workflow_json);
            put_u32(buf, *priority);
        }
        Message::JobStatus { job } => {
            buf.push(TAG_JOB_STATUS);
            put_u64(buf, *job);
        }
        Message::CancelJob { job } => {
            buf.push(TAG_CANCEL_JOB);
            put_u64(buf, *job);
        }
        Message::JobReport { jobs } => {
            buf.push(TAG_JOB_REPORT);
            put_u32(buf, jobs.len() as u32);
            for j in jobs {
                put_u64(buf, j.job);
                put_str(buf, &j.tenant);
                put_str(buf, &j.state);
                put_str(buf, &j.workflow);
                put_u64(buf, j.done);
                put_u64(buf, j.total);
                put_u64(buf, j.assigned);
                put_u64(buf, j.hits);
                put_u64(buf, j.cold);
                put_u64(buf, j.steals);
                put_u32(buf, j.priority);
                put_u64(buf, j.ops);
                put_u64(buf, j.busy_us);
            }
        }
        Message::GetJob { job } => {
            buf.push(TAG_GET_JOB);
            put_u64(buf, *job);
        }
        Message::JobSpec { job, tenant, workflow_json } => {
            buf.push(TAG_JOB_SPEC);
            put_u64(buf, *job);
            put_str(buf, tenant);
            put_str(buf, workflow_json);
        }
        Message::TraceBatch { worker, events } => {
            buf.push(TAG_TRACE_BATCH);
            put_u64(buf, *worker);
            buf.reserve(4 + events.len() * MIN_EVENT_BYTES);
            put_u32(buf, events.len() as u32);
            for ev in events {
                put_event(buf, ev);
            }
        }
        Message::StatsQuery => {
            buf.push(TAG_STATS_QUERY);
        }
        Message::StatsReport { rows } => {
            buf.push(TAG_STATS_REPORT);
            put_u32(buf, rows.len() as u32);
            for r in rows {
                put_util_row(buf, r);
            }
        }
    }
}

/// Decode a message payload.
pub fn decode(data: &[u8]) -> Result<Message> {
    let mut c = Cursor { data, pos: 0 };
    let version = c.u8()?;
    if version != PROTO_VERSION {
        return Err(Error::Net(format!(
            "protocol version {version}, expected {PROTO_VERSION} — mixed htap builds?"
        )));
    }
    let msg = match c.u8()? {
        TAG_REQUEST => {
            let capacity = c.u32()?;
            let worker = c.u64()?;
            let prefetch_budget = c.u32()?;
            let staged_add = c.ids()?;
            let staged_drop = c.ids()?;
            let demoted = c.ids()?;
            Message::Request {
                capacity,
                worker,
                prefetch_budget,
                staged_add,
                staged_drop,
                demoted,
            }
        }
        TAG_ASSIGN => {
            // id + stage + chunk + flags + input count = 25 bytes minimum
            let n = c.count(25)?;
            let mut assignments = Vec::with_capacity(n);
            for _ in 0..n {
                let instance_id = c.u64()?;
                let stage_idx = c.u32()? as usize;
                let chunk = c.u64()?;
                let flags = c.u8()?;
                let inputs = c.values()?;
                assignments.push(Assignment {
                    instance_id,
                    stage_idx,
                    chunk,
                    inputs,
                    needs_chunk: flags & FLAG_NEEDS_CHUNK != 0,
                    locality: flags & FLAG_LOCALITY != 0,
                    replica: flags & FLAG_REPLICA != 0,
                });
            }
            let prefetch = c.ids()?;
            let replicate = c.ids()?;
            Message::Assign { assignments, prefetch, replicate }
        }
        TAG_COMPLETE => {
            let instance = c.u64()?;
            let outputs = c.values()?;
            Message::Complete { instance, outputs }
        }
        TAG_FAIL => Message::Fail { msg: c.string()? },
        TAG_HELLO => Message::Hello { worker: c.u64()?, lease_ms: c.u64()? },
        TAG_HEARTBEAT => Message::Heartbeat { worker: c.u64()? },
        TAG_GOODBYE => Message::Goodbye { worker: c.u64()? },
        TAG_IDLE => Message::Idle,
        TAG_SUBMIT => {
            let tenant = c.string()?;
            let workflow_json = c.string()?;
            let priority = c.u32()?;
            Message::Submit { tenant, workflow_json, priority }
        }
        TAG_JOB_STATUS => Message::JobStatus { job: c.u64()? },
        TAG_CANCEL_JOB => Message::CancelJob { job: c.u64()? },
        TAG_JOB_REPORT => {
            // job + 3 string lengths + done/total/assigned +
            // hits/cold/steals + priority + ops/busy_us (v6)
            let n = c.count(88)?;
            let mut jobs = Vec::with_capacity(n);
            for _ in 0..n {
                let job = c.u64()?;
                let tenant = c.string()?;
                let state = c.string()?;
                let workflow = c.string()?;
                let done = c.u64()?;
                let total = c.u64()?;
                let assigned = c.u64()?;
                let hits = c.u64()?;
                let cold = c.u64()?;
                let steals = c.u64()?;
                let priority = c.u32()?;
                let ops = c.u64()?;
                let busy_us = c.u64()?;
                jobs.push(JobSummary {
                    job,
                    tenant,
                    state,
                    workflow,
                    done,
                    total,
                    assigned,
                    hits,
                    cold,
                    steals,
                    priority,
                    ops,
                    busy_us,
                });
            }
            Message::JobReport { jobs }
        }
        TAG_GET_JOB => Message::GetJob { job: c.u64()? },
        TAG_JOB_SPEC => {
            let job = c.u64()?;
            let tenant = c.string()?;
            let workflow_json = c.string()?;
            Message::JobSpec { job, tenant, workflow_json }
        }
        TAG_TRACE_BATCH => {
            let worker = c.u64()?;
            let n = c.count(MIN_EVENT_BYTES)?;
            let mut events = Vec::with_capacity(n);
            for _ in 0..n {
                events.push(c.event()?);
            }
            Message::TraceBatch { worker, events }
        }
        TAG_STATS_QUERY => Message::StatsQuery,
        TAG_STATS_REPORT => {
            let n = c.count(MIN_UTIL_ROW_BYTES)?;
            let mut rows = Vec::with_capacity(n);
            for _ in 0..n {
                rows.push(c.util_row()?);
            }
            Message::StatsReport { rows }
        }
        t => return Err(Error::Net(format!("unknown message tag {t}"))),
    };
    if c.pos != data.len() {
        return Err(Error::Net("trailing bytes in frame".into()));
    }
    Ok(msg)
}

/// Write one framed message.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> Result<()> {
    write_message_buf(w, msg, &mut Vec::new())
}

/// [`write_message`] encoding through a caller-owned scratch buffer.
/// Long-lived connections pass the same buffer every frame: after the
/// first large tensor the buffer's capacity sticks, so per-frame encoding
/// costs one bulk copy and zero allocations.
pub fn write_message_buf<W: Write>(w: &mut W, msg: &Message, scratch: &mut Vec<u8>) -> Result<()> {
    encode_into(msg, scratch);
    w.write_all(&(scratch.len() as u32).to_le_bytes())
        .and_then(|_| w.write_all(scratch))
        .and_then(|_| w.flush())
        .map_err(|e| Error::Net(e.to_string()))
}

/// Whether an I/O error is a socket read/write deadline expiring (the
/// `set_read_timeout`/`set_write_timeout` path), not a real failure.
/// Unix reports `WouldBlock`, Windows `TimedOut`; both mean "no bytes
/// yet, the peer may still be alive".
pub fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read one framed message.  Returns `Error::Net("eof")` on clean EOF
/// and `Error::Net("timeout")` when a socket read deadline expired
/// before the frame *started* (an expiry mid-frame is a real error: the
/// stream is desynced and the connection must be torn down).
pub fn read_message<R: Read>(r: &mut R) -> Result<Message> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Err(Error::Net("eof".into()))
        }
        Err(e) if is_timeout(&e) => return Err(Error::Net("timeout".into())),
        Err(e) => return Err(Error::Net(e.to_string())),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(Error::Net(format!("frame of {len} bytes exceeds cap")));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(|e| Error::Net(e.to_string()))?;
    decode(&payload)
}

/// Read one framed message off a stream whose socket has a read
/// timeout, looping on idle expiries while `keep_waiting` says to.
/// This is the idle-keepalive discipline: a slow-but-alive peer is never
/// torn down just because no frame arrived within one timeout window —
/// only a mid-frame stall (stream desync) or `keep_waiting() == false`
/// surfaces an error.  `BufRead` is required so the pre-frame wait can
/// use `fill_buf`, which consumes nothing on expiry: `read_exact` after
/// a partial read would lose bytes and desync the framing.
pub fn read_message_keepalive<R: std::io::BufRead>(
    r: &mut R,
    keep_waiting: impl Fn() -> bool,
) -> Result<Message> {
    loop {
        match r.fill_buf() {
            Ok([]) => return Err(Error::Net("eof".into())),
            Ok(_) => break, // frame bytes are flowing: commit to the read
            Err(e) if is_timeout(&e) => {
                if !keep_waiting() {
                    return Err(Error::Net("timeout".into()));
                }
            }
            Err(e) => return Err(Error::Net(e.to_string())),
        }
    }
    read_message(r)
}

/// Write one already-encoded payload as a frame, bypassing
/// [`encode_into`].  The fault-injection layer uses this to ship a
/// deliberately corrupted payload; the receiver must reject it as a
/// decode error, never misparse it.
pub fn write_raw_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())
        .and_then(|_| w.write_all(payload))
        .and_then(|_| w.flush())
        .map_err(|e| Error::Net(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let enc = encode(&msg);
        assert_eq!(decode(&enc).unwrap(), msg);
        // also through the framed writer/reader
        let mut buf = Vec::new();
        write_message(&mut buf, &msg).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_message(&mut cur).unwrap(), msg);
    }

    fn request(capacity: u32) -> Message {
        Message::Request {
            capacity,
            worker: 0,
            prefetch_budget: 0,
            staged_add: vec![],
            staged_drop: vec![],
            demoted: vec![],
        }
    }

    #[test]
    fn request_roundtrip() {
        roundtrip(request(7));
    }

    #[test]
    fn request_roundtrip_with_staging_hints() {
        roundtrip(Message::Request {
            capacity: 3,
            worker: 0xDEAD_BEEF_0042,
            prefetch_budget: 4,
            staged_add: vec![1, 5, 9],
            staged_drop: vec![2],
            demoted: vec![7, 8],
        });
    }

    #[test]
    fn assign_roundtrip_with_tensors() {
        roundtrip(Message::Assign {
            assignments: vec![Assignment {
                instance_id: 42,
                stage_idx: 1,
                chunk: 9,
                inputs: vec![
                    Value::Scalar(3.5),
                    Value::Tensor(HostTensor::new(vec![2, 3], vec![1.0; 6]).unwrap()),
                ],
                needs_chunk: false,
                locality: false,
                replica: false,
            }],
            prefetch: vec![],
            replicate: vec![],
        });
    }

    #[test]
    fn assign_roundtrip_with_staging_flags_and_hints() {
        // a deferred-chunk assignment ships no payload, just flags + hints
        roundtrip(Message::Assign {
            assignments: vec![
                Assignment {
                    instance_id: 7,
                    stage_idx: 0,
                    chunk: 3,
                    inputs: vec![],
                    needs_chunk: true,
                    locality: true,
                    replica: false,
                },
                Assignment {
                    instance_id: 8,
                    stage_idx: 1,
                    chunk: 4,
                    inputs: vec![Value::Scalar(1.0)],
                    needs_chunk: true,
                    locality: false,
                    replica: true,
                },
            ],
            prefetch: vec![5, 6, 7],
            replicate: vec![4],
        });
    }

    #[test]
    fn complete_and_fail_roundtrip() {
        roundtrip(Message::Complete {
            instance: 1,
            outputs: vec![Value::Tensor(HostTensor::new(vec![4], vec![0.5; 4]).unwrap())],
        });
        roundtrip(Message::Fail { msg: "boom — unicode ✓".into() });
    }

    #[test]
    fn empty_assign_means_done() {
        roundtrip(Message::Assign { assignments: vec![], prefetch: vec![], replicate: vec![] });
    }

    #[test]
    fn membership_messages_roundtrip() {
        roundtrip(Message::Hello { worker: 3, lease_ms: 3000 });
        roundtrip(Message::Hello { worker: u64::MAX, lease_ms: 0 });
        roundtrip(Message::Heartbeat { worker: 3 });
        roundtrip(Message::Goodbye { worker: 3 });
    }

    #[test]
    fn service_messages_roundtrip() {
        roundtrip(Message::Idle);
        roundtrip(Message::Submit {
            tenant: "alice".into(),
            workflow_json: "{\"name\":\"wf\"}".into(),
            priority: 4,
        });
        roundtrip(Message::JobStatus { job: 0 });
        roundtrip(Message::CancelJob { job: 9 });
        roundtrip(Message::JobReport { jobs: vec![] });
        roundtrip(Message::JobReport {
            jobs: vec![
                JobSummary {
                    job: 1,
                    tenant: "alice".into(),
                    state: "Running".into(),
                    workflow: "wsi".into(),
                    done: 3,
                    total: 33,
                    assigned: 5,
                    hits: 2,
                    cold: 1,
                    steals: 0,
                    priority: 1,
                    ops: 4,
                    busy_us: 1234,
                },
                JobSummary {
                    job: 2,
                    tenant: "bob — unicode ✓".into(),
                    state: "Queued".into(),
                    workflow: "generic".into(),
                    done: 0,
                    total: 10,
                    assigned: 0,
                    hits: 0,
                    cold: 0,
                    steals: 0,
                    priority: 4,
                    ops: 0,
                    busy_us: 0,
                },
            ],
        });
        roundtrip(Message::GetJob { job: 2 });
        roundtrip(Message::JobSpec {
            job: 2,
            tenant: "bob".into(),
            workflow_json: "{}".into(),
        });
    }

    #[test]
    fn truncated_service_frames_rejected() {
        let enc = encode(&Message::Submit {
            tenant: "t".into(),
            workflow_json: "{}".into(),
            priority: 1,
        });
        assert!(decode(&enc[..enc.len() - 1]).is_err());
        let mut enc = encode(&Message::Idle);
        enc.push(0); // trailing byte
        assert!(decode(&enc).is_err());
        // a hostile JobReport count must fail before preallocation
        let mut evil = vec![PROTO_VERSION, TAG_JOB_REPORT];
        put_u32(&mut evil, u32::MAX);
        assert!(decode(&evil).is_err());
    }

    fn event(kind: crate::obs::EventKind, ts_us: u64) -> TraceEvent {
        TraceEvent {
            ts_us,
            dur_us: 120,
            device: crate::obs::DEV_GPU,
            worker: 3,
            lane: 2,
            job: 7,
            stage: 1,
            chunk: 42,
            name: Name::new("normalization"),
            ..TraceEvent::of(kind)
        }
    }

    #[test]
    fn trace_messages_roundtrip() {
        roundtrip(Message::StatsQuery);
        roundtrip(Message::TraceBatch { worker: 0, events: vec![] });
        // every event kind must survive the wire — the kind byte is
        // validated on decode, so a missing arm would show up here
        let events: Vec<TraceEvent> =
            EventKind::ALL.iter().enumerate().map(|(i, &k)| event(k, i as u64 * 10)).collect();
        roundtrip(Message::TraceBatch { worker: 3, events });
        // unicode + empty names
        roundtrip(Message::TraceBatch {
            worker: 1,
            events: vec![
                TraceEvent { name: Name::new("op ✓ µs"), ..TraceEvent::of(EventKind::OpEnd) },
                TraceEvent::of(EventKind::Dropped),
            ],
        });
        roundtrip(Message::StatsReport { rows: vec![] });
        roundtrip(Message::StatsReport {
            rows: vec![
                UtilRow { worker: 1, job: 2, tenant: "alice".into(), ops: 9, busy_us: 4200 },
                UtilRow { worker: 2, job: 2, tenant: "bob — ✓".into(), ops: 1, busy_us: 17 },
            ],
        });
    }

    #[test]
    fn truncated_trace_frames_rejected() {
        // every strict prefix of a TraceBatch must be a decode error, not
        // a panic or a silently short batch
        let enc = encode(&Message::TraceBatch {
            worker: 3,
            events: vec![event(EventKind::OpEnd, 100), event(EventKind::StagingHit, 200)],
        });
        for cut in 1..enc.len() {
            assert!(decode(&enc[..cut]).is_err(), "prefix of {cut} bytes decoded");
        }
        let enc = encode(&Message::StatsReport {
            rows: vec![UtilRow { worker: 1, job: 1, tenant: "t".into(), ops: 1, busy_us: 1 }],
        });
        for cut in 1..enc.len() {
            assert!(decode(&enc[..cut]).is_err(), "prefix of {cut} bytes decoded");
        }
        // a hostile event count must fail before preallocation
        let mut evil = vec![PROTO_VERSION, TAG_TRACE_BATCH];
        put_u64(&mut evil, 1); // worker
        put_u32(&mut evil, u32::MAX);
        assert!(decode(&evil).is_err());
        let mut evil = vec![PROTO_VERSION, TAG_STATS_REPORT];
        put_u32(&mut evil, u32::MAX);
        assert!(decode(&evil).is_err());
        // an unknown kind byte is a decode error, not a transmuted enum
        let mut enc = encode(&Message::TraceBatch {
            worker: 1,
            events: vec![event(EventKind::OpBegin, 5)],
        });
        let kind_at = 1 + 1 + 8 + 4 + 8 + 8; // version, tag, worker, count, ts, dur
        enc[kind_at] = 0xEE;
        let err = decode(&enc).unwrap_err();
        assert!(err.to_string().contains("kind"), "{err}");
    }

    #[test]
    fn truncated_membership_frames_rejected() {
        let enc = encode(&Message::Hello { worker: 7, lease_ms: 500 });
        assert!(decode(&enc[..enc.len() - 1]).is_err());
        let enc = encode(&Message::Heartbeat { worker: 7 });
        assert!(decode(&enc[..enc.len() - 1]).is_err());
        let mut enc = encode(&Message::Goodbye { worker: 7 });
        enc.push(0); // trailing byte
        assert!(decode(&enc).is_err());
    }

    #[test]
    fn version_mismatch_is_a_decode_error() {
        let mut enc = encode(&request(1));
        assert_eq!(enc[0], PROTO_VERSION);
        enc[0] = PROTO_VERSION - 1; // a v5 peer without the trace messages
        let err = decode(&enc).unwrap_err();
        assert!(err.to_string().contains("protocol version"), "{err}");
        // and through the framed reader
        let mut framed = Vec::new();
        framed.extend_from_slice(&(enc.len() as u32).to_le_bytes());
        framed.extend_from_slice(&enc);
        let mut cur = std::io::Cursor::new(framed);
        assert!(read_message(&mut cur).is_err());
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(decode(&[99]).is_err()); // bogus version byte
        assert!(decode(&[PROTO_VERSION, 99]).is_err()); // unknown tag
        assert!(decode(&[PROTO_VERSION, TAG_REQUEST, 1]).is_err()); // truncated
        // overflowing tensor dims must be a decode error, not a wrapping
        // product (which would panic in debug or smuggle in a tensor whose
        // shape disagrees with its data in release)
        let mut evil = vec![PROTO_VERSION, TAG_COMPLETE];
        put_u64(&mut evil, 7); // instance
        put_u32(&mut evil, 1); // one output value
        evil.push(1); // tensor tag
        put_u32(&mut evil, 2); // rank 2
        put_u64(&mut evil, 1 << 62); // dims whose product wraps to 0
        put_u64(&mut evil, 4);
        let err = decode(&evil).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        let mut enc = encode(&request(1));
        enc.push(0); // trailing byte
        assert!(decode(&enc).is_err());
        // oversized frame header
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cur = std::io::Cursor::new(buf);
        assert!(read_message(&mut cur).is_err());
    }

    #[test]
    fn encode_into_reuses_the_scratch_buffer() {
        let big = Message::Complete {
            instance: 1,
            outputs: vec![Value::Tensor(HostTensor::new(vec![64, 64], vec![0.5; 4096]).unwrap())],
        };
        let mut scratch = Vec::new();
        encode_into(&big, &mut scratch);
        assert_eq!(decode(&scratch).unwrap(), big);
        let cap = scratch.capacity();
        assert!(cap >= 4096 * 4);
        // a smaller frame reuses the grown allocation (no realloc, no
        // stale bytes from the previous frame)
        let small = Message::Fail { msg: "x".into() };
        encode_into(&small, &mut scratch);
        assert_eq!(scratch.capacity(), cap, "scratch capacity must be retained");
        assert_eq!(decode(&scratch).unwrap(), small);
        // and the framed writer through the same scratch stays correct
        let mut wire = Vec::new();
        write_message_buf(&mut wire, &big, &mut scratch).unwrap();
        let mut cur = std::io::Cursor::new(wire);
        assert_eq!(read_message(&mut cur).unwrap(), big);
    }

    #[test]
    fn tensor_frames_are_bit_exact() {
        // the bulk f32 copy must produce the exact per-element LE layout
        let vals = vec![1.5f32, -2.25, 0.0, f32::MAX];
        let msg = Message::Complete {
            instance: 9,
            outputs: vec![Value::Tensor(HostTensor::new(vec![4], vals.clone()).unwrap())],
        };
        let enc = encode(&msg);
        // payload tail is the raw f32 LE bytes
        let tail = &enc[enc.len() - 16..];
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(&tail[i * 4..(i + 1) * 4], &v.to_le_bytes());
        }
        match decode(&enc).unwrap() {
            Message::Complete { outputs, .. } => {
                let t = outputs[0].as_tensor().unwrap();
                for (a, b) in t.data().iter().zip(&vals) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("bad decode: {other:?}"),
        }
    }

    #[test]
    fn eof_is_distinguishable() {
        let mut cur = std::io::Cursor::new(Vec::<u8>::new());
        match read_message(&mut cur) {
            Err(crate::Error::Net(e)) => assert_eq!(e, "eof"),
            other => panic!("expected eof, got {other:?}"),
        }
    }
}
