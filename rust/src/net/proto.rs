//! Length-prefixed binary framing for the Manager/Worker protocol.
//!
//! Frame layout: `u32 LE length` + payload.  Payload starts with a one-byte
//! message tag; tensors are shipped as rank + dims + raw f32 LE bytes (a
//! 4Kx4K tile is ~192 MB as JSON but 64 MB raw — binary matters here).

use crate::coordinator::manager::Assignment;
use crate::runtime::{HostTensor, Value};
use crate::{Error, Result};
use std::io::{Read, Write};

/// Maximum accepted frame (guards against corrupt length prefixes).
const MAX_FRAME: u32 = 1 << 30;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker -> Manager: give me up to `capacity` stage instances.
    Request { capacity: u32 },
    /// Manager -> Worker: assignments (empty = workflow complete).
    Assign { assignments: Vec<Assignment> },
    /// Worker -> Manager: stage instance finished.
    Complete { instance: u64, outputs: Vec<Value> },
    /// Worker -> Manager: fatal worker error.
    Fail { msg: String },
}

const TAG_REQUEST: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_COMPLETE: u8 = 3;
const TAG_FAIL: u8 = 4;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_value(buf: &mut Vec<u8>, v: &Value) {
    match v {
        Value::Scalar(s) => {
            buf.push(0);
            buf.extend_from_slice(&s.to_le_bytes());
        }
        Value::Tensor(t) => {
            buf.push(1);
            put_u32(buf, t.shape().len() as u32);
            for &d in t.shape() {
                put_u64(buf, d as u64);
            }
            for &f in t.data() {
                buf.extend_from_slice(&f.to_le_bytes());
            }
        }
    }
}

fn put_values(buf: &mut Vec<u8>, vals: &[Value]) {
    put_u32(buf, vals.len() as u32);
    for v in vals {
        put_value(buf, v);
    }
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            return Err(Error::Net("truncated frame".into()));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn value(&mut self) -> Result<Value> {
        match self.u8()? {
            0 => Ok(Value::Scalar(self.f32()?)),
            1 => {
                let rank = self.u32()? as usize;
                if rank > 8 {
                    return Err(Error::Net(format!("tensor rank {rank} too large")));
                }
                let mut dims = Vec::with_capacity(rank);
                for _ in 0..rank {
                    dims.push(self.u64()? as usize);
                }
                let n: usize = dims.iter().product();
                let bytes = self.take(n * 4)?;
                let mut data = Vec::with_capacity(n);
                for c in bytes.chunks_exact(4) {
                    data.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
                Ok(Value::Tensor(HostTensor::new(dims, data)?))
            }
            t => Err(Error::Net(format!("bad value tag {t}"))),
        }
    }

    fn values(&mut self) -> Result<Vec<Value>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.value()).collect()
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| Error::Net("bad utf8".into()))
    }
}

/// Encode a message (without the length prefix).
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut buf = Vec::new();
    match msg {
        Message::Request { capacity } => {
            buf.push(TAG_REQUEST);
            put_u32(&mut buf, *capacity);
        }
        Message::Assign { assignments } => {
            buf.push(TAG_ASSIGN);
            put_u32(&mut buf, assignments.len() as u32);
            for a in assignments {
                put_u64(&mut buf, a.instance_id);
                put_u32(&mut buf, a.stage_idx as u32);
                put_u64(&mut buf, a.chunk);
                put_values(&mut buf, &a.inputs);
            }
        }
        Message::Complete { instance, outputs } => {
            buf.push(TAG_COMPLETE);
            put_u64(&mut buf, *instance);
            put_values(&mut buf, outputs);
        }
        Message::Fail { msg } => {
            buf.push(TAG_FAIL);
            put_u32(&mut buf, msg.len() as u32);
            buf.extend_from_slice(msg.as_bytes());
        }
    }
    buf
}

/// Decode a message payload.
pub fn decode(data: &[u8]) -> Result<Message> {
    let mut c = Cursor { data, pos: 0 };
    let msg = match c.u8()? {
        TAG_REQUEST => Message::Request { capacity: c.u32()? },
        TAG_ASSIGN => {
            let n = c.u32()? as usize;
            let mut assignments = Vec::with_capacity(n);
            for _ in 0..n {
                let instance_id = c.u64()?;
                let stage_idx = c.u32()? as usize;
                let chunk = c.u64()?;
                let inputs = c.values()?;
                assignments.push(Assignment { instance_id, stage_idx, chunk, inputs });
            }
            Message::Assign { assignments }
        }
        TAG_COMPLETE => {
            let instance = c.u64()?;
            let outputs = c.values()?;
            Message::Complete { instance, outputs }
        }
        TAG_FAIL => Message::Fail { msg: c.string()? },
        t => return Err(Error::Net(format!("unknown message tag {t}"))),
    };
    if c.pos != data.len() {
        return Err(Error::Net("trailing bytes in frame".into()));
    }
    Ok(msg)
}

/// Write one framed message.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> Result<()> {
    let payload = encode(msg);
    w.write_all(&(payload.len() as u32).to_le_bytes())
        .and_then(|_| w.write_all(&payload))
        .and_then(|_| w.flush())
        .map_err(|e| Error::Net(e.to_string()))
}

/// Read one framed message.  Returns `Error::Net("eof")` on clean EOF.
pub fn read_message<R: Read>(r: &mut R) -> Result<Message> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Err(Error::Net("eof".into()))
        }
        Err(e) => return Err(Error::Net(e.to_string())),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(Error::Net(format!("frame of {len} bytes exceeds cap")));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(|e| Error::Net(e.to_string()))?;
    decode(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let enc = encode(&msg);
        assert_eq!(decode(&enc).unwrap(), msg);
        // also through the framed writer/reader
        let mut buf = Vec::new();
        write_message(&mut buf, &msg).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_message(&mut cur).unwrap(), msg);
    }

    #[test]
    fn request_roundtrip() {
        roundtrip(Message::Request { capacity: 7 });
    }

    #[test]
    fn assign_roundtrip_with_tensors() {
        roundtrip(Message::Assign {
            assignments: vec![Assignment {
                instance_id: 42,
                stage_idx: 1,
                chunk: 9,
                inputs: vec![
                    Value::Scalar(3.5),
                    Value::Tensor(HostTensor::new(vec![2, 3], vec![1.0; 6]).unwrap()),
                ],
            }],
        });
    }

    #[test]
    fn complete_and_fail_roundtrip() {
        roundtrip(Message::Complete {
            instance: 1,
            outputs: vec![Value::Tensor(HostTensor::new(vec![4], vec![0.5; 4]).unwrap())],
        });
        roundtrip(Message::Fail { msg: "boom — unicode ✓".into() });
    }

    #[test]
    fn empty_assign_means_done() {
        roundtrip(Message::Assign { assignments: vec![] });
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(decode(&[99]).is_err());
        assert!(decode(&[TAG_REQUEST, 1]).is_err()); // truncated
        let mut enc = encode(&Message::Request { capacity: 1 });
        enc.push(0); // trailing byte
        assert!(decode(&enc).is_err());
        // oversized frame header
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cur = std::io::Cursor::new(buf);
        assert!(read_message(&mut cur).is_err());
    }

    #[test]
    fn eof_is_distinguishable() {
        let mut cur = std::io::Cursor::new(Vec::<u8>::new());
        match read_message(&mut cur) {
            Err(crate::Error::Net(e)) => assert_eq!(e, "eof"),
            other => panic!("expected eof, got {other:?}"),
        }
    }
}
