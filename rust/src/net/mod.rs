//! Distributed Manager/Worker over TCP (the MPI substitute).
//!
//! The paper runs the Manager and Workers as MPI processes; MPI is not
//! available here, so the same demand-driven window protocol (paper
//! §III-B) runs over two TCP connections per Worker:
//!
//! * a **work channel** — the Worker's requester sends `Request{capacity,
//!   worker, staged-chunk deltas, prefetch budget}` and blocks until the
//!   Manager answers `Assign{assignments, prefetch hints}` (empty =
//!   workflow complete, shut down); in staged mode assignments defer the
//!   chunk payload to the worker's own chunk source, so tiles never cross
//!   the wire;
//! * a **completion channel** — the Worker's completer streams
//!   `Complete{instance, outputs}` messages back.
//!
//! Splitting the channels lets requesting overlap completing exactly like
//! the in-process Worker (worker.rs); message framing is length-prefixed
//! binary (`proto`).
//!
//! Membership is **elastic** (proto v4): the accept loop runs until the
//! workflow completes, so workers may join (or rejoin) a running manager
//! at any point.  A worker announces itself with `Hello{worker, lease
//! term}` on both channels, keeps its lease alive with `Heartbeat`s (or
//! just by requesting work), and departs cleanly with `Goodbye`.  A
//! sweeper thread expires workers that miss their lease: their in-flight
//! stage instances are re-issued to the survivors and their catalog
//! entries are purged, which is also exactly what happens when a
//! connection drops mid-run — crash tolerance and planned elasticity are
//! the same code path.
//!
//! Service mode (proto v5) reuses the very same server: [`ManagerServer`]
//! serves any [`Endpoint`] — the single-job `Manager` or the multi-tenant
//! `service::JobTable`.  Clients submit workflows (`Submit`), query and
//! cancel jobs (`JobStatus` / `CancelJob`), and workers fetch the
//! workflow behind a job-tagged assignment (`GetJob`).  A service
//! endpoint answers an unsatisfiable `Request` with `Idle` ("poll
//! again") instead of the empty `Assign` that means "shut down".  The
//! one-shot client calls ([`submit_job`], [`job_reports`],
//! [`cancel_job`], [`fetch_job_spec`]) each use a short-lived
//! connection, so control traffic never blocks behind a work channel.
//!
//! Observability (proto v6) piggybacks on the same channels: a tracing
//! worker ships its drained event rings as fire-and-forget `TraceBatch`
//! frames on the completion channel (heartbeat cadence, so tracing adds
//! no connections and no round-trips), and `htap top` polls the live
//! per-worker utilization with a one-shot `StatsQuery` ([`utilization`]).

pub mod proto;

use crate::coordinator::manager::{WorkBatch, WorkRequest, WorkSource};
use crate::data::staging::WorkerId;
use crate::faults::{Faults, Injection, Site};
use crate::obs::{self, EventKind, TraceEvent, Tracer, UtilRow};
use crate::runtime::sync::{self, Mutex};
use crate::runtime::Value;
use crate::service::{Endpoint, JobSummary};
use crate::{Error, Result};
use proto::Message;
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How often the manager scans member leases for expiry.  Much shorter
/// than any sensible lease term, so detection latency is dominated by the
/// lease itself, not the sweep cadence.
const LEASE_SWEEP_MS: u64 = 50;

/// Socket read deadline on server-side connections.  Not a liveness
/// verdict — an expiry only unblocks the connection thread so it can
/// re-check the stop flag (idle keepalive); slow-but-alive peers stay
/// connected and lease expiry remains the sweeper's job.
const SERVER_READ_TIMEOUT_MS: u64 = 250;

/// Socket read deadline on client-side channels.  Same keepalive
/// discipline: a blocked `Request` legitimately waits minutes for its
/// `Assign`, so expiries loop; only EOF/reset tears the channel down.
const CLIENT_READ_TIMEOUT_MS: u64 = 500;

/// Socket write deadline everywhere: a peer that stops draining its
/// receive window for this long is treated as gone (the reconnect path
/// on clients, connection teardown + lease requeue on the server).
const WRITE_TIMEOUT_MS: u64 = 10_000;

/// Completions kept for replay after a reconnect.  The manager ignores
/// duplicates (`stale_completions`), so replaying the recent tail is
/// safe; the cap bounds worker memory, not correctness — anything older
/// has long been journaled or will be re-issued via lease requeue.
const REPLAY_CAP: usize = 32;

/// Error-message marker for faults the injection layer manufactured.
/// Injected frame drops are retried in place (resend); everything else
/// tears the channel down and reconnects.
const INJECTED: &str = "injected:";

fn is_injected(e: &Error) -> bool {
    matches!(e, Error::Net(m) if m.starts_with(INJECTED))
}

fn net_err(e: std::io::Error) -> Error {
    Error::Net(e.to_string())
}

/// Bounded, deterministic exponential backoff shared by every RPC path:
/// worker→manager connect, request, complete, heartbeat, the server's
/// shutdown self-poke, and the one-shot control calls.  Deliberately no
/// jitter — retry timing must be a pure function of the attempt number
/// so chaos runs replay bit-identically and the model/lint suites stay
/// valid.  (Workers already desynchronise naturally: their attempt
/// clocks start at independent failure times.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (>= 1); the last failure is returned.
    pub max_attempts: u32,
    /// Backoff before the second attempt, doubling per attempt.
    pub base_ms: u64,
    /// Ceiling on any single backoff.
    pub cap_ms: u64,
}

impl RetryPolicy {
    /// In-band RPC default: ~5 attempts over a few seconds.
    pub fn rpc() -> RetryPolicy {
        RetryPolicy { max_attempts: 5, base_ms: 50, cap_ms: 2000 }
    }

    /// Reconnect/failover default: patient enough to ride out a standby
    /// promotion window (~10 attempts, ~13 s of cumulative backoff).
    pub fn reconnect() -> RetryPolicy {
        RetryPolicy { max_attempts: 10, base_ms: 100, cap_ms: 2000 }
    }

    /// Backoff after attempt `attempt` (0-based): `base * 2^attempt`,
    /// capped.  Deterministic by design.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        self.base_ms.saturating_mul(1u64 << attempt.min(20)).min(self.cap_ms)
    }

    /// Run `op` until it succeeds or attempts are exhausted, sleeping
    /// the deterministic backoff between attempts.
    pub fn run<T>(&self, mut op: impl FnMut(u32) -> Result<T>) -> Result<T> {
        let attempts = self.max_attempts.max(1);
        let mut last = Error::Net("retry: no attempts".into());
        for attempt in 0..attempts {
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => last = e,
            }
            if attempt + 1 < attempts {
                std::thread::sleep(Duration::from_millis(self.backoff_ms(attempt)));
            }
        }
        Err(last)
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::rpc()
    }
}

/// Serve an in-process [`Endpoint`] (a single-job `Manager` or the
/// service-mode `JobTable`) to remote Workers and control clients.
/// Returns once the endpoint reports done and all workers disconnected.
pub struct ManagerServer {
    listener: TcpListener,
    endpoint: Arc<dyn Endpoint>,
    stop: Arc<AtomicBool>,
}

impl ManagerServer {
    pub fn bind(addr: &str, endpoint: Arc<dyn Endpoint>) -> Result<Self> {
        let listener = TcpListener::bind(addr).map_err(|e| Error::Net(e.to_string()))?;
        Ok(ManagerServer { listener, endpoint, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> String {
        self.listener.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Elastic accept-and-serve loop.  Spawns one thread per accepted
    /// connection and keeps accepting until the workflow completes (or a
    /// worker reports a fatal error), so workers may join and leave while
    /// the run is in progress.  Two helper threads drive liveness: a
    /// completion watcher that unblocks the accept loop once the Manager
    /// reports done, and a lease sweeper that expires workers which missed
    /// their heartbeat term (their leases are re-issued to survivors).
    pub fn serve(&self) -> Result<()> {
        let watcher = {
            let ep = self.endpoint.clone();
            let stop = self.stop.clone();
            let addr = self.local_addr();
            std::thread::spawn(move || {
                ep.wait_done();
                stop.store(true, Ordering::SeqCst);
                // poke the listener so the blocking accept() observes the
                // stop flag instead of waiting for one more worker.  A
                // failed poke would leave the accept loop (and therefore
                // serve()) blocked forever, so it retries with backoff and
                // the final failure is at least visible to the operator.
                let poke = RetryPolicy::rpc();
                if let Err(e) =
                    poke.run(|_| TcpStream::connect(&addr).map(|_| ()).map_err(net_err))
                {
                    eprintln!(
                        "htap manager: shutdown self-poke to {addr} failed after \
                         {} attempts ({e}); accept loop may linger until the next \
                         connection",
                        poke.max_attempts
                    );
                }
            })
        };
        let sweeper = {
            let ep = self.endpoint.clone();
            let stop = self.stop.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(std::time::Duration::from_millis(LEASE_SWEEP_MS));
                    for (worker, requeued) in ep.sweep_leases() {
                        eprintln!(
                            "htap manager: worker {worker} missed its lease; \
                             re-issued {requeued} stage instances"
                        );
                    }
                }
            })
        };
        let mut handles = Vec::new();
        loop {
            let (stream, _) = self.listener.accept().map_err(|e| Error::Net(e.to_string()))?;
            if self.stop.load(Ordering::SeqCst) {
                // the watcher's poke (or an external stop): workflow done
                break;
            }
            let ep = self.endpoint.clone();
            let stop = self.stop.clone();
            handles.push(std::thread::spawn(move || serve_connection(stream, ep, stop)));
        }
        for h in handles {
            let _ = h.join();
        }
        let _ = watcher.join();
        let _ = sweeper.join();
        Ok(())
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }
}

fn serve_connection(stream: TcpStream, ep: Arc<dyn Endpoint>, stop: Arc<AtomicBool>) {
    // leases handed out on this connection; if the worker dies (EOF or
    // protocol error) before completing them, they are re-issued to the
    // surviving workers — the fault-tolerance path.
    let mut leases: Vec<u64> = Vec::new();
    let mut worker_id = 0u64;
    let mut clean = false;
    let result = serve_connection_inner(stream, &ep, &stop, &mut leases, &mut worker_id, &mut clean);
    let requeued = ep.requeue_stale(&leases);
    // the channel closed: whatever this worker had staged is gone — purge
    // it from the catalog so its chunks go back to cold instead of being
    // "stolen" from a ghost for the rest of the run.  (A `Goodbye` already
    // did this; repeating it is a no-op.)
    ep.purge_worker(worker_id);
    if let Err(e) = result {
        if requeued > 0 && !clean {
            eprintln!("htap manager: worker lost ({e}); re-issued {requeued} stage instances");
        }
    }
}

fn serve_connection_inner(
    stream: TcpStream,
    ep: &Arc<dyn Endpoint>,
    stop: &Arc<AtomicBool>,
    leases: &mut Vec<u64>,
    worker_id: &mut u64,
    clean: &mut bool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // read deadline + keepalive loop below: an idle (or hung) peer no
    // longer pins this thread past shutdown, but a slow-and-alive one is
    // never torn down — only the lease sweeper renders liveness verdicts.
    stream
        .set_read_timeout(Some(Duration::from_millis(SERVER_READ_TIMEOUT_MS)))
        .map_err(net_err)?;
    stream
        .set_write_timeout(Some(Duration::from_millis(WRITE_TIMEOUT_MS)))
        .map_err(net_err)?;
    let mut reader = BufReader::new(stream.try_clone().map_err(net_err)?);
    let mut writer = BufWriter::new(stream);
    // one frame buffer per connection: tensor frames encode into it with a
    // single bulk copy and its capacity is reused for the connection's life
    let mut scratch: Vec<u8> = Vec::new();
    loop {
        let msg = match proto::read_message_keepalive(&mut reader, || !stop.load(Ordering::SeqCst))
        {
            Ok(m) => m,
            Err(Error::Net(ref e)) if e == "eof" => return Ok(()),
            // stop flag observed while idle between frames: clean shutdown
            Err(Error::Net(ref e)) if e == "timeout" => {
                *clean = true;
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        match msg {
            Message::Request {
                capacity,
                worker,
                prefetch_budget,
                staged_add,
                staged_drop,
                demoted,
            } => {
                *worker_id = worker;
                let req = WorkRequest {
                    capacity: capacity.max(1) as usize,
                    worker,
                    staged_add,
                    staged_drop,
                    demoted,
                    prefetch_budget: prefetch_budget as usize,
                };
                let batch = ep.request_work(&req);
                let reply = if batch.idle && batch.assignments.is_empty() {
                    // service endpoint with nothing assignable right now:
                    // tell the worker to poll again, not to shut down
                    Message::Idle
                } else {
                    leases.extend(batch.assignments.iter().map(|a| a.instance_id));
                    Message::Assign {
                        assignments: batch.assignments,
                        prefetch: batch.prefetch,
                        replicate: batch.replicate,
                    }
                };
                proto::write_message_buf(&mut writer, &reply, &mut scratch)?;
            }
            Message::Complete { instance, outputs } => {
                ep.complete(instance, outputs);
                // completion channel is one-way; no ack needed
            }
            Message::Fail { msg } => {
                ep.fail(msg);
            }
            Message::Hello { worker, lease_ms } => {
                // membership announcement: remembers the worker id for
                // purge attribution on disconnect, and (lease_ms > 0)
                // enrolls the worker in lease tracking
                *worker_id = worker;
                ep.register_worker(worker, lease_ms);
            }
            Message::Heartbeat { worker } => {
                ep.heartbeat_worker(worker);
            }
            Message::Goodbye { worker } => {
                // planned departure: deregister + purge immediately so the
                // sweeper never reports this worker as lost
                *clean = true;
                ep.expire_worker(worker);
            }
            Message::Submit { tenant, workflow_json, priority } => {
                // admission verdict travels back as a one-entry JobReport
                // (accepted) or Fail (rejected) on the same connection
                let reply = match ep.submit(&tenant, &workflow_json, priority) {
                    Ok(job) => Message::JobReport { jobs: ep.job_report(job) },
                    Err(e) => Message::Fail { msg: e.to_string() },
                };
                proto::write_message_buf(&mut writer, &reply, &mut scratch)?;
            }
            Message::JobStatus { job } => {
                let jobs = ep.job_report(job);
                proto::write_message_buf(&mut writer, &Message::JobReport { jobs }, &mut scratch)?;
            }
            Message::CancelJob { job } => {
                let reply = match ep.cancel_job(job) {
                    Ok(()) => Message::JobReport { jobs: ep.job_report(job) },
                    Err(e) => Message::Fail { msg: e.to_string() },
                };
                proto::write_message_buf(&mut writer, &reply, &mut scratch)?;
            }
            Message::GetJob { job } => {
                let reply = match ep.job_spec(job) {
                    Ok((tenant, workflow_json)) => {
                        Message::JobSpec { job, tenant, workflow_json }
                    }
                    Err(e) => Message::Fail { msg: e.to_string() },
                };
                proto::write_message_buf(&mut writer, &reply, &mut scratch)?;
            }
            Message::TraceBatch { worker, events } => {
                // completion channel, fire-and-forget: merge and move on
                ep.trace_batch(worker, events);
            }
            Message::StatsQuery => {
                let rows = ep.utilization();
                proto::write_message_buf(
                    &mut writer,
                    &Message::StatsReport { rows },
                    &mut scratch,
                )?;
            }
            other => {
                return Err(Error::Net(format!("unexpected message {other:?} on server")));
            }
        }
    }
}

/// The work channel: request/assign round trips.
struct WorkChan {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    scratch: Vec<u8>,
}

impl WorkChan {
    fn new(stream: TcpStream) -> Result<WorkChan> {
        let wr = stream.try_clone().map_err(net_err)?;
        Ok(WorkChan {
            reader: BufReader::new(stream),
            writer: BufWriter::new(wr),
            scratch: Vec::new(),
        })
    }
}

/// The completion channel: one-way completions / membership / traces.
struct CompChan {
    writer: BufWriter<TcpStream>,
    scratch: Vec<u8>,
}

/// Client-side [`WorkSource`] speaking the protocol over two sockets.
/// Each channel owns a reusable frame buffer — the completion channel
/// ships every stage output tensor, so per-frame allocation matters.
///
/// The client is **self-healing** (proto v7 behaviour, same frames): a
/// channel is `None` while down, and each path re-dials through the
/// shared [`RetryPolicy`], walking the `addrs` failover list (primary
/// first, then standbys) from the last address that answered.  A
/// reconnect re-`Hello`s under the original worker identity, fires the
/// resync hook so the staging cache re-advertises every chunk it holds,
/// and replays the buffered completion tail — the manager drops the
/// duplicates (`stale_completions`), so replay is always safe.  The two
/// channels recover independently: the requester owns the work channel,
/// the heartbeat cadence doubles as the completion channel's
/// reconnection driver, and neither ever blocks on the other's lock.
pub struct RemoteManager {
    addrs: Vec<String>,
    retry: RetryPolicy,
    faults: Faults,
    /// Index into `addrs` of the last successful dial; reconnects start
    /// here so both channels converge on the same (promoted) manager.
    active: std::sync::atomic::AtomicUsize,
    work: Mutex<Option<WorkChan>>,
    completion: Mutex<Option<CompChan>>,
    /// `(worker, lease_ms)` from `register`, replayed as the `Hello` of
    /// every reconnected channel so the manager sees one continuous
    /// worker, not a stranger.
    identity: Mutex<Option<(WorkerId, u64)>>,
    /// Reconnect hook: tells the staging cache to re-advertise its full
    /// staged/spill set on the next `Request` (a promoted standby's
    /// catalog is only as fresh as the last checkpoint).
    resync: Mutex<Option<ResyncFn>>,
    /// Tail of recently sent completions, replayed after a reconnect in
    /// case the originals died in a TCP buffer.  Lock order: completion
    /// before replay, everywhere.
    replay: Mutex<VecDeque<(u64, Vec<Value>)>>,
    /// Frame send/recv events land here (disabled by default).
    tracer: Tracer,
    tx_frames: obs::Counter,
    tx_bytes: obs::Counter,
    rx_frames: obs::Counter,
    reconnects: obs::Counter,
}

/// Callback a [`WorkSource`] fires after reconnecting to (possibly) a
/// different manager, so worker-side state can be re-advertised.
pub type ResyncFn = Arc<dyn Fn() + Send + Sync>;

impl RemoteManager {
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with_obs(addr, &obs::Registry::new(), Tracer::disabled())
    }

    /// [`RemoteManager::connect`] with instrumentation: frame/byte
    /// counters register as `net.*` in `registry`, and every work-channel
    /// frame records a `FrameSend`/`FrameRecv` event when `tracer` is
    /// enabled (`chunk` carries the payload size in bytes).
    pub fn connect_with_obs(addr: &str, registry: &obs::Registry, tracer: Tracer) -> Result<Self> {
        Self::connect_opts(
            &[addr.to_string()],
            registry,
            tracer,
            Faults::disabled(),
            RetryPolicy::rpc(),
        )
    }

    /// Full-control constructor: `addrs` is the failover list (primary
    /// first), `faults` the armed injection handle, `retry` the policy
    /// every connect/request/complete shares.
    pub fn connect_opts(
        addrs: &[String],
        registry: &obs::Registry,
        tracer: Tracer,
        faults: Faults,
        retry: RetryPolicy,
    ) -> Result<Self> {
        if addrs.is_empty() {
            return Err(Error::Net("no manager address".into()));
        }
        let active = std::sync::atomic::AtomicUsize::new(0);
        let work = Self::dial_one(addrs, &retry, &faults, &active)?;
        let completion = Self::dial_one(addrs, &retry, &faults, &active)?;
        Ok(RemoteManager {
            addrs: addrs.to_vec(),
            retry,
            faults,
            active,
            work: Mutex::new(Some(WorkChan::new(work)?)),
            completion: Mutex::new(Some(CompChan {
                writer: BufWriter::new(completion),
                scratch: Vec::new(),
            })),
            identity: Mutex::new(None),
            resync: Mutex::new(None),
            replay: Mutex::new(VecDeque::new()),
            tracer,
            tx_frames: registry.counter("net.tx_frames"),
            tx_bytes: registry.counter("net.tx_bytes"),
            rx_frames: registry.counter("net.rx_frames"),
            reconnects: registry.counter("net.reconnects"),
        })
    }

    /// Dial one stream, walking the failover list from the last address
    /// that answered, with retry/backoff and the connect-refusal fault
    /// site applied per attempt.
    fn dial_one(
        addrs: &[String],
        retry: &RetryPolicy,
        faults: &Faults,
        active: &std::sync::atomic::AtomicUsize,
    ) -> Result<TcpStream> {
        let start = active.load(Ordering::Relaxed);
        retry.run(|attempt| {
            let idx = (start + attempt as usize) % addrs.len();
            let addr = &addrs[idx];
            if faults.inject(Site::Connect).is_some() {
                return Err(Error::Net(format!("{INJECTED} connect refused ({addr})")));
            }
            let stream = TcpStream::connect(addr).map_err(net_err)?;
            stream.set_nodelay(true).ok();
            stream
                .set_read_timeout(Some(Duration::from_millis(CLIENT_READ_TIMEOUT_MS)))
                .map_err(net_err)?;
            stream
                .set_write_timeout(Some(Duration::from_millis(WRITE_TIMEOUT_MS)))
                .map_err(net_err)?;
            active.store(idx, Ordering::Relaxed);
            Ok(stream)
        })
    }

    /// Write one data-plane frame with the framing fault sites applied.
    /// An injected drop returns an `injected:` error *without touching
    /// the socket* — the retry layer resends, so exactly one copy
    /// reaches the server per successful attempt and a dropped `Request`
    /// can never deadlock against its own `Assign`.  Control-plane
    /// frames (`Hello`/`Heartbeat`/`Goodbye`/`TraceBatch`) bypass this
    /// helper: a chaos plan must not silently unregister a worker.
    fn send_frame<W: std::io::Write>(
        faults: &Faults,
        writer: &mut W,
        scratch: &mut Vec<u8>,
        msg: &Message,
    ) -> Result<()> {
        if faults.is_armed() {
            if faults.inject(Site::FrameDrop).is_some() {
                return Err(Error::Net(format!("{INJECTED} frame dropped")));
            }
            faults.maybe_stall(Site::FrameDelay);
            faults.maybe_stall(Site::WriteStall);
            if faults.inject(Site::FrameCorrupt).is_some() {
                // flip the version byte: the receiver must reject the
                // frame (tearing the connection down) rather than ever
                // misparse its payload
                proto::encode_into(msg, scratch);
                if let Some(b) = scratch.first_mut() {
                    *b ^= 0x80;
                }
                return proto::write_raw_frame(writer, scratch);
            }
        }
        proto::write_message_buf(writer, msg, scratch)
    }

    fn current_identity(&self) -> Option<(WorkerId, u64)> {
        sync::lock_or_poisoned(&self.identity).ok().and_then(|g| *g)
    }

    /// Re-establish the work channel.  Called with the work lock held
    /// (the caller owns `chan`); never touches the completion lock.
    fn reconnect_work(&self, chan: &mut Option<WorkChan>) -> Result<()> {
        *chan = None;
        let stream = Self::dial_one(&self.addrs, &self.retry, &self.faults, &self.active)?;
        let mut fresh = WorkChan::new(stream)?;
        if let Some((worker, lease_ms)) = self.current_identity() {
            proto::write_message_buf(
                &mut fresh.writer,
                &Message::Hello { worker, lease_ms },
                &mut fresh.scratch,
            )?;
        }
        *chan = Some(fresh);
        self.reconnects.inc();
        // the manager on the other end may be a freshly promoted standby
        // whose catalog is checkpoint-stale: re-advertise everything this
        // worker holds on the next Request
        let resync = sync::lock_or_poisoned(&self.resync).ok().and_then(|g| g.clone());
        if let Some(cb) = resync {
            cb();
        }
        Ok(())
    }

    /// Re-establish the completion channel and replay the buffered
    /// completion tail.  Called with the completion lock held; never
    /// touches the work lock (lock order: completion before replay).
    fn reconnect_completion(&self, chan: &mut Option<CompChan>) -> Result<()> {
        *chan = None;
        let stream = Self::dial_one(&self.addrs, &self.retry, &self.faults, &self.active)?;
        let mut fresh = CompChan { writer: BufWriter::new(stream), scratch: Vec::new() };
        if let Some((worker, lease_ms)) = self.current_identity() {
            proto::write_message_buf(
                &mut fresh.writer,
                &Message::Hello { worker, lease_ms },
                &mut fresh.scratch,
            )?;
        }
        // replay the recent tail in order: completions that died in a TCP
        // buffer are re-delivered, already-landed ones are dropped by the
        // manager as stale duplicates.  Replays bypass injection — a
        // recovery path that re-rolls the fault dice never converges.
        let tail: Vec<(u64, Vec<Value>)> = match sync::lock_or_poisoned(&self.replay) {
            Ok(r) => r.iter().cloned().collect(),
            Err(_) => Vec::new(),
        };
        for (instance, outputs) in tail {
            proto::write_message_buf(
                &mut fresh.writer,
                &Message::Complete { instance, outputs },
                &mut fresh.scratch,
            )?;
            self.note_tx(fresh.scratch.len());
        }
        *chan = Some(fresh);
        self.reconnects.inc();
        Ok(())
    }

    /// One request/assign round trip on the current work channel.
    fn try_request(&self, chan: &mut Option<WorkChan>, msg: &Message) -> Result<WorkBatch> {
        let ch = chan.as_mut().ok_or_else(|| Error::Net("work channel down".into()))?;
        Self::send_frame(&self.faults, &mut ch.writer, &mut ch.scratch, msg)?;
        self.note_tx(ch.scratch.len());
        self.faults.maybe_stall(Site::ReadStall);
        // wait patiently while the channel is healthy: a blocked Request
        // legitimately waits for stragglers ahead of it in the window,
        // and heartbeats ride the other channel.  A dead manager surfaces
        // as EOF/reset here, which the retry loop turns into a reconnect.
        match proto::read_message_keepalive(&mut ch.reader, || true) {
            Ok(Message::Assign { assignments, prefetch, replicate }) => {
                self.rx_frames.inc();
                self.tracer.record(TraceEvent {
                    chunk: assignments.len() as u64,
                    ..TraceEvent::of(EventKind::FrameRecv)
                });
                Ok(WorkBatch { assignments, prefetch, replicate, idle: false })
            }
            // service endpoint, nothing assignable right now: surface the
            // poll-again marker so the worker sleeps instead of exiting
            Ok(Message::Idle) => {
                self.rx_frames.inc();
                Ok(WorkBatch { idle: true, ..WorkBatch::default() })
            }
            Ok(other) => Err(Error::Net(format!("unexpected reply {other:?}"))),
            Err(e) => Err(e),
        }
    }

    /// Count (and, when tracing, record) one sent frame of `bytes` bytes.
    fn note_tx(&self, bytes: usize) {
        self.tx_frames.inc();
        self.tx_bytes.add(bytes as u64);
        self.tracer.record(TraceEvent {
            chunk: bytes as u64,
            ..TraceEvent::of(EventKind::FrameSend)
        });
    }

    /// Fire-and-forget a control-plane message on the completion channel
    /// (no fault injection — see [`RemoteManager::send_frame`]).  Returns
    /// whether the write succeeded; a failure marks the channel down so
    /// the next heartbeat reconnects it.
    fn send_completion(&self, msg: &Message) -> bool {
        let Ok(mut chan) = sync::lock_or_poisoned(&self.completion) else {
            return false;
        };
        let Some(ch) = chan.as_mut() else {
            return false;
        };
        if proto::write_message_buf(&mut ch.writer, msg, &mut ch.scratch).is_err() {
            *chan = None;
            return false;
        }
        true
    }
}

impl WorkSource for RemoteManager {
    fn request_work(&self, req: &WorkRequest) -> WorkBatch {
        // chaos site: a paused worker must only ever look slow (its lease
        // is kept alive by the heartbeat thread), never wrong
        self.faults.maybe_stall(Site::WorkerPause);
        // a poisoned channel means a frame writer panicked mid-stream: the
        // connection state is unusable, so report "workflow over" and let
        // the worker wind down instead of cascading the panic
        let Ok(mut chan) = sync::lock_or_poisoned(&self.work) else {
            return WorkBatch::default();
        };
        let msg = Message::Request {
            capacity: req.capacity as u32,
            worker: req.worker,
            prefetch_budget: req.prefetch_budget as u32,
            staged_add: req.staged_add.clone(),
            staged_drop: req.staged_drop.clone(),
            demoted: req.demoted.clone(),
        };
        let attempts = self.retry.max_attempts.max(1);
        let mut attempt = 0u32;
        loop {
            match self.try_request(&mut chan, &msg) {
                Ok(batch) => return batch,
                Err(e) => {
                    if !is_injected(&e) {
                        // real I/O failure: the channel state is suspect
                        *chan = None;
                    }
                    attempt += 1;
                    if attempt >= attempts {
                        eprintln!(
                            "htap worker: giving up on manager after {attempts} \
                             request attempts ({e})"
                        );
                        return WorkBatch::default();
                    }
                    std::thread::sleep(Duration::from_millis(self.retry.backoff_ms(attempt - 1)));
                    if chan.is_none() {
                        // reconnect failures just consume attempts; the
                        // next try_request reports the channel as down
                        let _ = self.reconnect_work(&mut chan);
                    }
                }
            }
        }
    }

    fn complete(&self, instance_id: u64, outputs: Vec<Value>) {
        // poisoned → drop the completion; the manager's fault-tolerance
        // path re-issues the lease when the connection dies
        let Ok(mut chan) = sync::lock_or_poisoned(&self.completion) else {
            return;
        };
        // remember the tail for replay-after-reconnect before trying to
        // send: a completion that dies in a TCP buffer is invisible here
        if let Ok(mut r) = sync::lock_or_poisoned(&self.replay) {
            r.push_back((instance_id, outputs.clone()));
            while r.len() > REPLAY_CAP {
                r.pop_front();
            }
        }
        let msg = Message::Complete { instance: instance_id, outputs };
        let attempts = self.retry.max_attempts.max(1);
        for attempt in 0..attempts {
            match chan.as_mut() {
                Some(ch) => {
                    match Self::send_frame(&self.faults, &mut ch.writer, &mut ch.scratch, &msg) {
                        Ok(()) => {
                            let bytes = ch.scratch.len();
                            self.note_tx(bytes);
                            return;
                        }
                        // injected drop: the frame never left, resend on
                        // the same (healthy) channel after backoff
                        Err(ref e) if is_injected(e) => {}
                        Err(_) => *chan = None,
                    }
                }
                None => {
                    // a successful reconnect replays the ring, which
                    // includes this completion — done
                    if self.reconnect_completion(&mut chan).is_ok() {
                        return;
                    }
                }
            }
            if attempt + 1 < attempts {
                std::thread::sleep(Duration::from_millis(self.retry.backoff_ms(attempt)));
            }
        }
        // still down: the completion stays in the replay ring and ships
        // with the next successful (heartbeat-driven) reconnect; if even
        // that never comes, the lease sweeper re-issues the instance
    }

    fn register(&self, worker: WorkerId, lease_ms: u64) {
        // remembered so every reconnected channel re-Hellos as the same
        // worker — reconnect-and-resume, not a stranger joining
        if let Ok(mut id) = sync::lock_or_poisoned(&self.identity) {
            *id = Some((worker, lease_ms));
        }
        // Hello goes out on *both* channels so each server-side connection
        // thread learns the worker id for purge attribution on disconnect
        // (the work channel also learns it from the first Request, but a
        // worker can die before ever requesting).
        let msg = Message::Hello { worker, lease_ms };
        if let Ok(mut chan) = sync::lock_or_poisoned(&self.work) {
            if let Some(ch) = chan.as_mut() {
                let _ = proto::write_message_buf(&mut ch.writer, &msg, &mut ch.scratch);
            }
        }
        self.send_completion(&msg);
    }

    fn heartbeat(&self, worker: WorkerId) {
        // never the work channel: a Request may be blocked on its Assign
        // there, and the whole point of heartbeats is staying alive while
        // long stage instances keep the work channel busy
        if !self.send_completion(&Message::Heartbeat { worker }) {
            // the completion channel is down; the heartbeat cadence
            // doubles as its reconnection driver (the requester never
            // holds this lock, so no cross-channel blocking)
            if let Ok(mut chan) = sync::lock_or_poisoned(&self.completion) {
                if chan.is_none() {
                    let _ = self.reconnect_completion(&mut chan);
                }
            }
        }
    }

    fn goodbye(&self, worker: WorkerId) {
        self.send_completion(&Message::Goodbye { worker });
    }

    fn trace_events(&self, worker: WorkerId, events: Vec<TraceEvent>) {
        // fire-and-forget on the completion channel, like heartbeats; the
        // batch itself is deliberately not counted as a FrameSend (the
        // trace transport must not feed its own trace)
        self.send_completion(&Message::TraceBatch { worker, events });
    }

    fn set_resync(&self, resync: ResyncFn) {
        if let Ok(mut cb) = sync::lock_or_poisoned(&self.resync) {
            *cb = Some(resync);
        }
    }
}

/// Read deadline for one-shot control calls: the reply to a control
/// frame is computed immediately, so a silent peer this long is down.
const ONE_SHOT_TIMEOUT_MS: u64 = 5000;

/// One round-trip over a short-lived connection: connect, send `msg`,
/// read the reply, disconnect.  Control traffic (submit / status /
/// cancel / job-spec fetch) stays off the long-lived work channels, so a
/// blocked `Request` can never stall a status query.  A server-side
/// `Fail` reply is surfaced as the error it carries.
fn call_service_once(addr: &str, msg: &Message) -> Result<Message> {
    let stream = TcpStream::connect(addr).map_err(net_err)?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(ONE_SHOT_TIMEOUT_MS)))
        .map_err(net_err)?;
    stream
        .set_write_timeout(Some(Duration::from_millis(WRITE_TIMEOUT_MS)))
        .map_err(net_err)?;
    let mut reader = BufReader::new(stream.try_clone().map_err(net_err)?);
    let mut writer = BufWriter::new(stream);
    proto::write_message(&mut writer, msg)?;
    match proto::read_message(&mut reader)? {
        Message::Fail { msg } => Err(Error::Scheduler(msg)),
        reply => Ok(reply),
    }
}

/// [`call_service_once`] with retry/backoff across a failover list.
/// Transport errors rotate to the next address; an application-level
/// `Fail` came over a healthy connection, so retrying cannot change the
/// verdict and it returns immediately.
pub fn call_service_at(addrs: &[String], msg: &Message, retry: &RetryPolicy) -> Result<Message> {
    if addrs.is_empty() {
        return Err(Error::Net("no manager address".into()));
    }
    let attempts = retry.max_attempts.max(1);
    let mut last = Error::Net("retry: no attempts".into());
    for attempt in 0..attempts {
        let addr = &addrs[attempt as usize % addrs.len()];
        match call_service_once(addr, msg) {
            Ok(reply) => return Ok(reply),
            Err(e @ Error::Scheduler(_)) => return Err(e),
            Err(e) => last = e,
        }
        if attempt + 1 < attempts {
            std::thread::sleep(Duration::from_millis(retry.backoff_ms(attempt)));
        }
    }
    Err(last)
}

fn call_service(addr: &str, msg: &Message) -> Result<Message> {
    call_service_at(&[addr.to_string()], msg, &RetryPolicy::rpc())
}

/// Single-attempt liveness probe — no retry, no backoff: can `addr`
/// answer a `StatsQuery` right now?  The standby's failure detector
/// wants the raw verdict each tick; patience is its own policy.
pub fn probe(addr: &str) -> Result<()> {
    call_service_once(addr, &Message::StatsQuery).map(|_| ())
}

/// Submit a workflow to a service-mode manager; returns the accepted
/// job's summary (state `Queued` or already `Running`).
pub fn submit_job(
    addr: &str,
    tenant: &str,
    workflow_json: &str,
    priority: u32,
) -> Result<JobSummary> {
    let msg = Message::Submit {
        tenant: tenant.to_string(),
        workflow_json: workflow_json.to_string(),
        priority,
    };
    match call_service(addr, &msg)? {
        Message::JobReport { mut jobs } if !jobs.is_empty() => Ok(jobs.remove(0)),
        other => Err(Error::Net(format!("unexpected submit reply {other:?}"))),
    }
}

/// Fetch job summaries from a service-mode manager: one row for `job`,
/// or every job the service knows when `job == 0`.
pub fn job_reports(addr: &str, job: u64) -> Result<Vec<JobSummary>> {
    match call_service(addr, &Message::JobStatus { job })? {
        Message::JobReport { jobs } => Ok(jobs),
        other => Err(Error::Net(format!("unexpected status reply {other:?}"))),
    }
}

/// Cancel a queued or running job; returns its post-cancel summary.
pub fn cancel_job(addr: &str, job: u64) -> Result<JobSummary> {
    match call_service(addr, &Message::CancelJob { job })? {
        Message::JobReport { mut jobs } if !jobs.is_empty() => Ok(jobs.remove(0)),
        other => Err(Error::Net(format!("unexpected cancel reply {other:?}"))),
    }
}

/// Fetch a job's `(tenant, workflow_json)` — workers call this the first
/// time they see an assignment tagged with a job they haven't compiled.
pub fn fetch_job_spec(addr: &str, job: u64) -> Result<(String, String)> {
    fetch_job_spec_at(&[addr.to_string()], job, &RetryPolicy::rpc())
}

/// [`fetch_job_spec`] across a failover list: a worker resolving a job
/// mid-failover asks whichever manager answers.
pub fn fetch_job_spec_at(
    addrs: &[String],
    job: u64,
    retry: &RetryPolicy,
) -> Result<(String, String)> {
    match call_service_at(addrs, &Message::GetJob { job }, retry)? {
        Message::JobSpec { tenant, workflow_json, .. } => Ok((tenant, workflow_json)),
        other => Err(Error::Net(format!("unexpected job-spec reply {other:?}"))),
    }
}

/// Poll a running manager/service for its live per-(worker, job)
/// utilization rows — the `htap top` feed (proto v6 `StatsQuery`).
pub fn utilization(addr: &str) -> Result<Vec<UtilRow>> {
    match call_service(addr, &Message::StatsQuery)? {
        Message::StatsReport { rows } => Ok(rows),
        other => Err(Error::Net(format!("unexpected stats reply {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::manager::{AssignPolicy, Manager};
    use crate::dataflow::{OpRegistry, StageKind, Workflow, WorkflowBuilder};
    use crate::runtime::Value;
    use crate::service::JobTable;

    fn tiny_workflow() -> Arc<Workflow> {
        let mut reg = OpRegistry::new();
        reg.register_cpu("double", 1, |args: &[Value]| {
            Ok(vec![Value::Scalar(args[0].as_scalar()? * 2.0)])
        })
        .unwrap();
        let mut wb = WorkflowBuilder::new("net-test", reg);
        let mut s = wb.stage("double", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let d = s.add_op("double", &[chunk]).unwrap();
        s.export(d.out()).unwrap();
        wb.add_stage(s).unwrap();
        Arc::new(wb.build().unwrap())
    }

    #[test]
    fn retry_policy_backoff_is_deterministic_and_bounded() {
        let p = RetryPolicy { max_attempts: 6, base_ms: 50, cap_ms: 400 };
        let seq: Vec<u64> = (0..6).map(|a| p.backoff_ms(a)).collect();
        assert_eq!(seq, vec![50, 100, 200, 400, 400, 400]);
        // run() surfaces the final error once attempts are exhausted...
        let mut calls = 0;
        let r: Result<()> = RetryPolicy { max_attempts: 3, base_ms: 0, cap_ms: 0 }.run(|_| {
            calls += 1;
            Err(Error::Net("nope".into()))
        });
        assert!(r.is_err());
        assert_eq!(calls, 3);
        // ...and returns the first success without further attempts
        let mut calls = 0;
        let r = RetryPolicy { max_attempts: 5, base_ms: 0, cap_ms: 0 }.run(|a| {
            calls += 1;
            if a == 2 {
                Ok(a)
            } else {
                Err(Error::Net("not yet".into()))
            }
        });
        assert_eq!(r.unwrap(), 2);
        assert_eq!(calls, 3);
    }

    #[test]
    fn remote_protocol_round_trip() {
        let wf = tiny_workflow();
        let loader: crate::coordinator::ChunkLoader =
            Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
        let mgr = Manager::new(wf, loader, 5).unwrap();
        let server = ManagerServer::bind("127.0.0.1:0", mgr.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());

        let remote = RemoteManager::connect(&addr).unwrap();
        let mut executed = 0;
        loop {
            let batch = remote.request(2);
            if batch.is_empty() {
                break;
            }
            for a in batch {
                let v = a.inputs[0].as_scalar().unwrap();
                remote.complete(a.instance_id, vec![Value::Scalar(v * 2.0)]);
                executed += 1;
            }
        }
        assert_eq!(executed, 5);
        drop(remote);
        srv.join().unwrap().unwrap();
        let (done, total) = mgr.progress();
        assert_eq!(done, total);
        assert!(mgr.error().is_none());
    }

    #[test]
    fn membership_messages_reach_the_manager() {
        let wf = tiny_workflow();
        let loader: crate::coordinator::ChunkLoader =
            Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
        let mgr = Manager::new(wf, loader, 3).unwrap();
        let server = ManagerServer::bind("127.0.0.1:0", mgr.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());

        let remote = RemoteManager::connect(&addr).unwrap();
        remote.register(7, 60_000);
        remote.heartbeat(7);
        // membership messages are async; wait for the server thread to
        // process them before asserting
        for _ in 0..200 {
            if mgr.member_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(mgr.member_count(), 1);

        // drain the workflow so serve() returns, then depart cleanly
        loop {
            let batch = remote.request(4);
            if batch.is_empty() {
                break;
            }
            for a in batch {
                let v = a.inputs[0].as_scalar().unwrap();
                remote.complete(a.instance_id, vec![Value::Scalar(v * 2.0)]);
            }
        }
        remote.goodbye(7);
        drop(remote);
        srv.join().unwrap().unwrap();
        assert_eq!(mgr.member_count(), 0);
        assert!(mgr.error().is_none());
    }

    #[test]
    fn trace_batches_and_stats_polls_flow_over_tcp() {
        let wf = tiny_workflow();
        let loader: crate::coordinator::ChunkLoader =
            Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
        let mgr = Manager::new(wf, loader, 3).unwrap();
        let server = ManagerServer::bind("127.0.0.1:0", mgr.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());

        let remote = RemoteManager::connect(&addr).unwrap();
        // a drained worker ring ships on the completion channel...
        remote.trace_events(
            5,
            vec![TraceEvent {
                ts_us: 10,
                dur_us: 7,
                worker: 5,
                job: 1,
                ..TraceEvent::of(EventKind::OpEnd)
            }],
        );
        // ...and lands in the manager's collector (async channel)
        for _ in 0..200 {
            if !mgr.collector().is_empty() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(mgr.collector().len(), 1);

        // the htap-top poll sees the merged rollup over a one-shot call
        let rows = utilization(&addr).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!((rows[0].worker, rows[0].job), (5, 1));
        assert_eq!((rows[0].ops, rows[0].busy_us), (1, 7));

        // drain the workflow so serve() returns
        loop {
            let batch = remote.request(4);
            if batch.is_empty() {
                break;
            }
            for a in batch {
                let v = a.inputs[0].as_scalar().unwrap();
                remote.complete(a.instance_id, vec![Value::Scalar(v * 2.0)]);
            }
        }
        drop(remote);
        srv.join().unwrap().unwrap();
        assert!(mgr.error().is_none());
    }

    const SERVICE_WF: &str = r#"{
        "name": "double-sum",
        "stages": [
            {
                "name": "double", "kind": "per_chunk", "inputs": ["chunk"],
                "ops": [ { "op": "double", "inputs": [ {"input": 0} ] } ],
                "outputs": [ {"op": "double"} ]
            },
            {
                "name": "total", "kind": "reduce",
                "inputs": [ {"stage": "double", "output": 0} ],
                "ops": [ { "op": "sum", "inputs": "all" } ],
                "outputs": [ {"op": "sum"} ]
            }
        ]
    }"#;

    fn service_registry() -> Arc<OpRegistry> {
        let mut r = OpRegistry::new();
        r.register_cpu("double", 1, |args: &[Value]| {
            Ok(vec![Value::Scalar(args[0].as_scalar()? * 2.0)])
        })
        .unwrap();
        r.register_cpu("sum", 1, |args: &[Value]| {
            let mut s = 0.0;
            for a in args {
                s += a.as_scalar()?;
            }
            Ok(vec![Value::Scalar(s)])
        })
        .unwrap();
        Arc::new(r)
    }

    #[test]
    fn service_mode_submissions_run_over_tcp() {
        let table = JobTable::new(service_registry(), 4, AssignPolicy::default(), 4, 8);
        let server = ManagerServer::bind("127.0.0.1:0", table.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());

        let accepted = submit_job(&addr, "alice", SERVICE_WF, 2).unwrap();
        assert_eq!(accepted.tenant, "alice");
        assert!(accepted.job >= 1);
        assert_eq!(accepted.priority, 2);

        // workers resolve the workflow behind a job id over the wire
        let (tenant, json) = fetch_job_spec(&addr, accepted.job).unwrap();
        assert_eq!(tenant, "alice");
        assert!(json.contains("double"));
        assert!(fetch_job_spec(&addr, 999).is_err());

        // one remote worker that understands the Idle poll-again marker
        let remote = RemoteManager::connect(&addr).unwrap();
        let worker = std::thread::spawn(move || loop {
            let req = WorkRequest { capacity: 2, worker: 1, ..Default::default() };
            let batch = WorkSource::request_work(&remote, &req);
            if batch.idle {
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            if batch.assignments.is_empty() {
                return; // real shutdown, not an idle lull
            }
            for a in batch.assignments {
                let out = if a.needs_chunk {
                    // per-chunk stage: payload is Scalar(chunk), doubled
                    Value::Scalar(a.chunk as f32 * 2.0)
                } else {
                    let mut s = 0.0;
                    for v in &a.inputs {
                        s += v.as_scalar().unwrap();
                    }
                    Value::Scalar(s)
                };
                remote.complete(a.instance_id, vec![out]);
            }
        });

        // poll the status API until the job reports Done
        let mut state = String::new();
        for _ in 0..2000 {
            let rows = job_reports(&addr, accepted.job).unwrap();
            state.clone_from(&rows[0].state);
            if state == "Done" {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(state, "Done");
        // chunks 0..4 doubled then summed: 0 + 2 + 4 + 6
        assert_eq!(
            table.reduce_outputs(accepted.job, "total"),
            Some(vec![Value::Scalar(12.0)])
        );
        // cancelling a finished job is rejected through the Fail reply
        assert!(cancel_job(&addr, accepted.job).is_err());

        table.shutdown();
        worker.join().unwrap();
        srv.join().unwrap().unwrap();
    }
}
