//! Distributed Manager/Worker over TCP (the MPI substitute).
//!
//! The paper runs the Manager and Workers as MPI processes; MPI is not
//! available here, so the same demand-driven window protocol (paper
//! §III-B) runs over two TCP connections per Worker:
//!
//! * a **work channel** — the Worker's requester sends `Request{capacity,
//!   worker, staged-chunk deltas, prefetch budget}` and blocks until the
//!   Manager answers `Assign{assignments, prefetch hints}` (empty =
//!   workflow complete, shut down); in staged mode assignments defer the
//!   chunk payload to the worker's own chunk source, so tiles never cross
//!   the wire;
//! * a **completion channel** — the Worker's completer streams
//!   `Complete{instance, outputs}` messages back.
//!
//! Splitting the channels lets requesting overlap completing exactly like
//! the in-process Worker (worker.rs); message framing is length-prefixed
//! binary (`proto`).
//!
//! Membership is **elastic** (proto v4): the accept loop runs until the
//! workflow completes, so workers may join (or rejoin) a running manager
//! at any point.  A worker announces itself with `Hello{worker, lease
//! term}` on both channels, keeps its lease alive with `Heartbeat`s (or
//! just by requesting work), and departs cleanly with `Goodbye`.  A
//! sweeper thread expires workers that miss their lease: their in-flight
//! stage instances are re-issued to the survivors and their catalog
//! entries are purged, which is also exactly what happens when a
//! connection drops mid-run — crash tolerance and planned elasticity are
//! the same code path.
//!
//! Service mode (proto v5) reuses the very same server: [`ManagerServer`]
//! serves any [`Endpoint`] — the single-job `Manager` or the multi-tenant
//! `service::JobTable`.  Clients submit workflows (`Submit`), query and
//! cancel jobs (`JobStatus` / `CancelJob`), and workers fetch the
//! workflow behind a job-tagged assignment (`GetJob`).  A service
//! endpoint answers an unsatisfiable `Request` with `Idle` ("poll
//! again") instead of the empty `Assign` that means "shut down".  The
//! one-shot client calls ([`submit_job`], [`job_reports`],
//! [`cancel_job`], [`fetch_job_spec`]) each use a short-lived
//! connection, so control traffic never blocks behind a work channel.
//!
//! Observability (proto v6) piggybacks on the same channels: a tracing
//! worker ships its drained event rings as fire-and-forget `TraceBatch`
//! frames on the completion channel (heartbeat cadence, so tracing adds
//! no connections and no round-trips), and `htap top` polls the live
//! per-worker utilization with a one-shot `StatsQuery` ([`utilization`]).

pub mod proto;

use crate::coordinator::manager::{WorkBatch, WorkRequest, WorkSource};
use crate::data::staging::WorkerId;
use crate::obs::{self, EventKind, TraceEvent, Tracer, UtilRow};
use crate::runtime::sync::{self, Mutex};
use crate::service::{Endpoint, JobSummary};
use crate::{Error, Result};
use proto::Message;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// How often the manager scans member leases for expiry.  Much shorter
/// than any sensible lease term, so detection latency is dominated by the
/// lease itself, not the sweep cadence.
const LEASE_SWEEP_MS: u64 = 50;

/// Serve an in-process [`Endpoint`] (a single-job `Manager` or the
/// service-mode `JobTable`) to remote Workers and control clients.
/// Returns once the endpoint reports done and all workers disconnected.
pub struct ManagerServer {
    listener: TcpListener,
    endpoint: Arc<dyn Endpoint>,
    stop: Arc<AtomicBool>,
}

impl ManagerServer {
    pub fn bind(addr: &str, endpoint: Arc<dyn Endpoint>) -> Result<Self> {
        let listener = TcpListener::bind(addr).map_err(|e| Error::Net(e.to_string()))?;
        Ok(ManagerServer { listener, endpoint, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> String {
        self.listener.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Elastic accept-and-serve loop.  Spawns one thread per accepted
    /// connection and keeps accepting until the workflow completes (or a
    /// worker reports a fatal error), so workers may join and leave while
    /// the run is in progress.  Two helper threads drive liveness: a
    /// completion watcher that unblocks the accept loop once the Manager
    /// reports done, and a lease sweeper that expires workers which missed
    /// their heartbeat term (their leases are re-issued to survivors).
    pub fn serve(&self) -> Result<()> {
        let watcher = {
            let ep = self.endpoint.clone();
            let stop = self.stop.clone();
            let addr = self.local_addr();
            std::thread::spawn(move || {
                ep.wait_done();
                stop.store(true, Ordering::SeqCst);
                // poke the listener so the blocking accept() observes the
                // stop flag instead of waiting for one more worker
                let _ = TcpStream::connect(&addr);
            })
        };
        let sweeper = {
            let ep = self.endpoint.clone();
            let stop = self.stop.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(std::time::Duration::from_millis(LEASE_SWEEP_MS));
                    for (worker, requeued) in ep.sweep_leases() {
                        eprintln!(
                            "htap manager: worker {worker} missed its lease; \
                             re-issued {requeued} stage instances"
                        );
                    }
                }
            })
        };
        let mut handles = Vec::new();
        loop {
            let (stream, _) = self.listener.accept().map_err(|e| Error::Net(e.to_string()))?;
            if self.stop.load(Ordering::SeqCst) {
                // the watcher's poke (or an external stop): workflow done
                break;
            }
            let ep = self.endpoint.clone();
            handles.push(std::thread::spawn(move || serve_connection(stream, ep)));
        }
        for h in handles {
            let _ = h.join();
        }
        let _ = watcher.join();
        let _ = sweeper.join();
        Ok(())
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }
}

fn serve_connection(stream: TcpStream, ep: Arc<dyn Endpoint>) {
    // leases handed out on this connection; if the worker dies (EOF or
    // protocol error) before completing them, they are re-issued to the
    // surviving workers — the fault-tolerance path.
    let mut leases: Vec<u64> = Vec::new();
    let mut worker_id = 0u64;
    let mut clean = false;
    let result = serve_connection_inner(stream, &ep, &mut leases, &mut worker_id, &mut clean);
    let requeued = ep.requeue_stale(&leases);
    // the channel closed: whatever this worker had staged is gone — purge
    // it from the catalog so its chunks go back to cold instead of being
    // "stolen" from a ghost for the rest of the run.  (A `Goodbye` already
    // did this; repeating it is a no-op.)
    ep.purge_worker(worker_id);
    if let Err(e) = result {
        if requeued > 0 && !clean {
            eprintln!("htap manager: worker lost ({e}); re-issued {requeued} stage instances");
        }
    }
}

fn serve_connection_inner(
    stream: TcpStream,
    ep: &Arc<dyn Endpoint>,
    leases: &mut Vec<u64>,
    worker_id: &mut u64,
    clean: &mut bool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| Error::Net(e.to_string()))?);
    let mut writer = BufWriter::new(stream);
    // one frame buffer per connection: tensor frames encode into it with a
    // single bulk copy and its capacity is reused for the connection's life
    let mut scratch: Vec<u8> = Vec::new();
    loop {
        let msg = match proto::read_message(&mut reader) {
            Ok(m) => m,
            Err(Error::Net(ref e)) if e == "eof" => return Ok(()),
            Err(e) => return Err(e),
        };
        match msg {
            Message::Request {
                capacity,
                worker,
                prefetch_budget,
                staged_add,
                staged_drop,
                demoted,
            } => {
                *worker_id = worker;
                let req = WorkRequest {
                    capacity: capacity.max(1) as usize,
                    worker,
                    staged_add,
                    staged_drop,
                    demoted,
                    prefetch_budget: prefetch_budget as usize,
                };
                let batch = ep.request_work(&req);
                let reply = if batch.idle && batch.assignments.is_empty() {
                    // service endpoint with nothing assignable right now:
                    // tell the worker to poll again, not to shut down
                    Message::Idle
                } else {
                    leases.extend(batch.assignments.iter().map(|a| a.instance_id));
                    Message::Assign {
                        assignments: batch.assignments,
                        prefetch: batch.prefetch,
                        replicate: batch.replicate,
                    }
                };
                proto::write_message_buf(&mut writer, &reply, &mut scratch)?;
            }
            Message::Complete { instance, outputs } => {
                ep.complete(instance, outputs);
                // completion channel is one-way; no ack needed
            }
            Message::Fail { msg } => {
                ep.fail(msg);
            }
            Message::Hello { worker, lease_ms } => {
                // membership announcement: remembers the worker id for
                // purge attribution on disconnect, and (lease_ms > 0)
                // enrolls the worker in lease tracking
                *worker_id = worker;
                ep.register_worker(worker, lease_ms);
            }
            Message::Heartbeat { worker } => {
                ep.heartbeat_worker(worker);
            }
            Message::Goodbye { worker } => {
                // planned departure: deregister + purge immediately so the
                // sweeper never reports this worker as lost
                *clean = true;
                ep.expire_worker(worker);
            }
            Message::Submit { tenant, workflow_json, priority } => {
                // admission verdict travels back as a one-entry JobReport
                // (accepted) or Fail (rejected) on the same connection
                let reply = match ep.submit(&tenant, &workflow_json, priority) {
                    Ok(job) => Message::JobReport { jobs: ep.job_report(job) },
                    Err(e) => Message::Fail { msg: e.to_string() },
                };
                proto::write_message_buf(&mut writer, &reply, &mut scratch)?;
            }
            Message::JobStatus { job } => {
                let jobs = ep.job_report(job);
                proto::write_message_buf(&mut writer, &Message::JobReport { jobs }, &mut scratch)?;
            }
            Message::CancelJob { job } => {
                let reply = match ep.cancel_job(job) {
                    Ok(()) => Message::JobReport { jobs: ep.job_report(job) },
                    Err(e) => Message::Fail { msg: e.to_string() },
                };
                proto::write_message_buf(&mut writer, &reply, &mut scratch)?;
            }
            Message::GetJob { job } => {
                let reply = match ep.job_spec(job) {
                    Ok((tenant, workflow_json)) => {
                        Message::JobSpec { job, tenant, workflow_json }
                    }
                    Err(e) => Message::Fail { msg: e.to_string() },
                };
                proto::write_message_buf(&mut writer, &reply, &mut scratch)?;
            }
            Message::TraceBatch { worker, events } => {
                // completion channel, fire-and-forget: merge and move on
                ep.trace_batch(worker, events);
            }
            Message::StatsQuery => {
                let rows = ep.utilization();
                proto::write_message_buf(
                    &mut writer,
                    &Message::StatsReport { rows },
                    &mut scratch,
                )?;
            }
            other => {
                return Err(Error::Net(format!("unexpected message {other:?} on server")));
            }
        }
    }
}

/// Client-side [`WorkSource`] speaking the protocol over two sockets.
/// Each channel owns a reusable frame buffer — the completion channel
/// ships every stage output tensor, so per-frame allocation matters.
pub struct RemoteManager {
    work: Mutex<(BufReader<TcpStream>, BufWriter<TcpStream>, Vec<u8>)>,
    completion: Mutex<(BufWriter<TcpStream>, Vec<u8>)>,
    /// Frame send/recv events land here (disabled by default).
    tracer: Tracer,
    tx_frames: obs::Counter,
    tx_bytes: obs::Counter,
    rx_frames: obs::Counter,
}

impl RemoteManager {
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with_obs(addr, &obs::Registry::new(), Tracer::disabled())
    }

    /// [`RemoteManager::connect`] with instrumentation: frame/byte
    /// counters register as `net.*` in `registry`, and every work-channel
    /// frame records a `FrameSend`/`FrameRecv` event when `tracer` is
    /// enabled (`chunk` carries the payload size in bytes).
    pub fn connect_with_obs(addr: &str, registry: &obs::Registry, tracer: Tracer) -> Result<Self> {
        let work = TcpStream::connect(addr).map_err(|e| Error::Net(e.to_string()))?;
        work.set_nodelay(true).ok();
        let completion = TcpStream::connect(addr).map_err(|e| Error::Net(e.to_string()))?;
        completion.set_nodelay(true).ok();
        let wr = work.try_clone().map_err(|e| Error::Net(e.to_string()))?;
        Ok(RemoteManager {
            work: Mutex::new((BufReader::new(work), BufWriter::new(wr), Vec::new())),
            completion: Mutex::new((BufWriter::new(completion), Vec::new())),
            tracer,
            tx_frames: registry.counter("net.tx_frames"),
            tx_bytes: registry.counter("net.tx_bytes"),
            rx_frames: registry.counter("net.rx_frames"),
        })
    }

    /// Count (and, when tracing, record) one sent frame of `bytes` bytes.
    fn note_tx(&self, bytes: usize) {
        self.tx_frames.inc();
        self.tx_bytes.add(bytes as u64);
        self.tracer.record(TraceEvent {
            chunk: bytes as u64,
            ..TraceEvent::of(EventKind::FrameSend)
        });
    }

    /// Fire-and-forget a membership message on the completion channel.
    /// Send failures are ignored: a broken channel means the manager is
    /// gone (or going), and the server-side disconnect path already covers
    /// cleanup.
    fn send_completion(&self, msg: &Message) {
        let Ok(mut chan) = sync::lock_or_poisoned(&self.completion) else {
            return;
        };
        let (writer, scratch) = &mut *chan;
        let _ = proto::write_message_buf(writer, msg, scratch);
    }
}

impl WorkSource for RemoteManager {
    fn request_work(&self, req: &WorkRequest) -> WorkBatch {
        // a poisoned channel means a frame writer panicked mid-stream: the
        // connection state is unusable, so report "workflow over" and let
        // the worker wind down instead of cascading the panic
        let Ok(mut chan) = sync::lock_or_poisoned(&self.work) else {
            return WorkBatch::default();
        };
        let (reader, writer, scratch) = &mut *chan;
        let msg = Message::Request {
            capacity: req.capacity as u32,
            worker: req.worker,
            prefetch_budget: req.prefetch_budget as u32,
            staged_add: req.staged_add.clone(),
            staged_drop: req.staged_drop.clone(),
            demoted: req.demoted.clone(),
        };
        if proto::write_message_buf(writer, &msg, scratch).is_err() {
            return WorkBatch::default();
        }
        self.note_tx(scratch.len());
        match proto::read_message(reader) {
            Ok(Message::Assign { assignments, prefetch, replicate }) => {
                self.rx_frames.inc();
                self.tracer.record(TraceEvent {
                    chunk: assignments.len() as u64,
                    ..TraceEvent::of(EventKind::FrameRecv)
                });
                WorkBatch { assignments, prefetch, replicate, idle: false }
            }
            // service endpoint, nothing assignable right now: surface the
            // poll-again marker so the worker sleeps instead of exiting
            Ok(Message::Idle) => WorkBatch { idle: true, ..WorkBatch::default() },
            _ => WorkBatch::default(),
        }
    }

    fn complete(&self, instance_id: u64, outputs: Vec<crate::runtime::Value>) {
        // poisoned → drop the completion; the manager's fault-tolerance
        // path re-issues the lease when the connection dies
        let Ok(mut chan) = sync::lock_or_poisoned(&self.completion) else {
            return;
        };
        let (writer, scratch) = &mut *chan;
        let sent = proto::write_message_buf(
            writer,
            &Message::Complete { instance: instance_id, outputs },
            scratch,
        )
        .is_ok();
        let bytes = scratch.len();
        drop(chan);
        if sent {
            self.note_tx(bytes);
        }
    }

    fn register(&self, worker: WorkerId, lease_ms: u64) {
        // Hello goes out on *both* channels so each server-side connection
        // thread learns the worker id for purge attribution on disconnect
        // (the work channel also learns it from the first Request, but a
        // worker can die before ever requesting).
        if let Ok(mut chan) = sync::lock_or_poisoned(&self.work) {
            let (_, writer, scratch) = &mut *chan;
            let _ =
                proto::write_message_buf(writer, &Message::Hello { worker, lease_ms }, scratch);
        }
        self.send_completion(&Message::Hello { worker, lease_ms });
    }

    fn heartbeat(&self, worker: WorkerId) {
        // never the work channel: a Request may be blocked on its Assign
        // there, and the whole point of heartbeats is staying alive while
        // long stage instances keep the work channel busy
        self.send_completion(&Message::Heartbeat { worker });
    }

    fn goodbye(&self, worker: WorkerId) {
        self.send_completion(&Message::Goodbye { worker });
    }

    fn trace_events(&self, worker: WorkerId, events: Vec<TraceEvent>) {
        // fire-and-forget on the completion channel, like heartbeats; the
        // batch itself is deliberately not counted as a FrameSend (the
        // trace transport must not feed its own trace)
        self.send_completion(&Message::TraceBatch { worker, events });
    }
}

/// One round-trip over a short-lived connection: connect, send `msg`,
/// read the reply, disconnect.  Control traffic (submit / status /
/// cancel / job-spec fetch) stays off the long-lived work channels, so a
/// blocked `Request` can never stall a status query.  A server-side
/// `Fail` reply is surfaced as the error it carries.
fn call_service(addr: &str, msg: &Message) -> Result<Message> {
    let stream = TcpStream::connect(addr).map_err(|e| Error::Net(e.to_string()))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| Error::Net(e.to_string()))?);
    let mut writer = BufWriter::new(stream);
    proto::write_message(&mut writer, msg)?;
    match proto::read_message(&mut reader)? {
        Message::Fail { msg } => Err(Error::Scheduler(msg)),
        reply => Ok(reply),
    }
}

/// Submit a workflow to a service-mode manager; returns the accepted
/// job's summary (state `Queued` or already `Running`).
pub fn submit_job(
    addr: &str,
    tenant: &str,
    workflow_json: &str,
    priority: u32,
) -> Result<JobSummary> {
    let msg = Message::Submit {
        tenant: tenant.to_string(),
        workflow_json: workflow_json.to_string(),
        priority,
    };
    match call_service(addr, &msg)? {
        Message::JobReport { mut jobs } if !jobs.is_empty() => Ok(jobs.remove(0)),
        other => Err(Error::Net(format!("unexpected submit reply {other:?}"))),
    }
}

/// Fetch job summaries from a service-mode manager: one row for `job`,
/// or every job the service knows when `job == 0`.
pub fn job_reports(addr: &str, job: u64) -> Result<Vec<JobSummary>> {
    match call_service(addr, &Message::JobStatus { job })? {
        Message::JobReport { jobs } => Ok(jobs),
        other => Err(Error::Net(format!("unexpected status reply {other:?}"))),
    }
}

/// Cancel a queued or running job; returns its post-cancel summary.
pub fn cancel_job(addr: &str, job: u64) -> Result<JobSummary> {
    match call_service(addr, &Message::CancelJob { job })? {
        Message::JobReport { mut jobs } if !jobs.is_empty() => Ok(jobs.remove(0)),
        other => Err(Error::Net(format!("unexpected cancel reply {other:?}"))),
    }
}

/// Fetch a job's `(tenant, workflow_json)` — workers call this the first
/// time they see an assignment tagged with a job they haven't compiled.
pub fn fetch_job_spec(addr: &str, job: u64) -> Result<(String, String)> {
    match call_service(addr, &Message::GetJob { job })? {
        Message::JobSpec { tenant, workflow_json, .. } => Ok((tenant, workflow_json)),
        other => Err(Error::Net(format!("unexpected job-spec reply {other:?}"))),
    }
}

/// Poll a running manager/service for its live per-(worker, job)
/// utilization rows — the `htap top` feed (proto v6 `StatsQuery`).
pub fn utilization(addr: &str) -> Result<Vec<UtilRow>> {
    match call_service(addr, &Message::StatsQuery)? {
        Message::StatsReport { rows } => Ok(rows),
        other => Err(Error::Net(format!("unexpected stats reply {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::manager::{AssignPolicy, Manager};
    use crate::dataflow::{OpRegistry, StageKind, Workflow, WorkflowBuilder};
    use crate::runtime::Value;
    use crate::service::JobTable;

    fn tiny_workflow() -> Arc<Workflow> {
        let mut reg = OpRegistry::new();
        reg.register_cpu("double", 1, |args: &[Value]| {
            Ok(vec![Value::Scalar(args[0].as_scalar()? * 2.0)])
        })
        .unwrap();
        let mut wb = WorkflowBuilder::new("net-test", reg);
        let mut s = wb.stage("double", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let d = s.add_op("double", &[chunk]).unwrap();
        s.export(d.out()).unwrap();
        wb.add_stage(s).unwrap();
        Arc::new(wb.build().unwrap())
    }

    #[test]
    fn remote_protocol_round_trip() {
        let wf = tiny_workflow();
        let loader: crate::coordinator::ChunkLoader =
            Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
        let mgr = Manager::new(wf, loader, 5).unwrap();
        let server = ManagerServer::bind("127.0.0.1:0", mgr.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());

        let remote = RemoteManager::connect(&addr).unwrap();
        let mut executed = 0;
        loop {
            let batch = remote.request(2);
            if batch.is_empty() {
                break;
            }
            for a in batch {
                let v = a.inputs[0].as_scalar().unwrap();
                remote.complete(a.instance_id, vec![Value::Scalar(v * 2.0)]);
                executed += 1;
            }
        }
        assert_eq!(executed, 5);
        drop(remote);
        srv.join().unwrap().unwrap();
        let (done, total) = mgr.progress();
        assert_eq!(done, total);
        assert!(mgr.error().is_none());
    }

    #[test]
    fn membership_messages_reach_the_manager() {
        let wf = tiny_workflow();
        let loader: crate::coordinator::ChunkLoader =
            Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
        let mgr = Manager::new(wf, loader, 3).unwrap();
        let server = ManagerServer::bind("127.0.0.1:0", mgr.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());

        let remote = RemoteManager::connect(&addr).unwrap();
        remote.register(7, 60_000);
        remote.heartbeat(7);
        // membership messages are async; wait for the server thread to
        // process them before asserting
        for _ in 0..200 {
            if mgr.member_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(mgr.member_count(), 1);

        // drain the workflow so serve() returns, then depart cleanly
        loop {
            let batch = remote.request(4);
            if batch.is_empty() {
                break;
            }
            for a in batch {
                let v = a.inputs[0].as_scalar().unwrap();
                remote.complete(a.instance_id, vec![Value::Scalar(v * 2.0)]);
            }
        }
        remote.goodbye(7);
        drop(remote);
        srv.join().unwrap().unwrap();
        assert_eq!(mgr.member_count(), 0);
        assert!(mgr.error().is_none());
    }

    #[test]
    fn trace_batches_and_stats_polls_flow_over_tcp() {
        let wf = tiny_workflow();
        let loader: crate::coordinator::ChunkLoader =
            Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
        let mgr = Manager::new(wf, loader, 3).unwrap();
        let server = ManagerServer::bind("127.0.0.1:0", mgr.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());

        let remote = RemoteManager::connect(&addr).unwrap();
        // a drained worker ring ships on the completion channel...
        remote.trace_events(
            5,
            vec![TraceEvent {
                ts_us: 10,
                dur_us: 7,
                worker: 5,
                job: 1,
                ..TraceEvent::of(EventKind::OpEnd)
            }],
        );
        // ...and lands in the manager's collector (async channel)
        for _ in 0..200 {
            if !mgr.collector().is_empty() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(mgr.collector().len(), 1);

        // the htap-top poll sees the merged rollup over a one-shot call
        let rows = utilization(&addr).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!((rows[0].worker, rows[0].job), (5, 1));
        assert_eq!((rows[0].ops, rows[0].busy_us), (1, 7));

        // drain the workflow so serve() returns
        loop {
            let batch = remote.request(4);
            if batch.is_empty() {
                break;
            }
            for a in batch {
                let v = a.inputs[0].as_scalar().unwrap();
                remote.complete(a.instance_id, vec![Value::Scalar(v * 2.0)]);
            }
        }
        drop(remote);
        srv.join().unwrap().unwrap();
        assert!(mgr.error().is_none());
    }

    const SERVICE_WF: &str = r#"{
        "name": "double-sum",
        "stages": [
            {
                "name": "double", "kind": "per_chunk", "inputs": ["chunk"],
                "ops": [ { "op": "double", "inputs": [ {"input": 0} ] } ],
                "outputs": [ {"op": "double"} ]
            },
            {
                "name": "total", "kind": "reduce",
                "inputs": [ {"stage": "double", "output": 0} ],
                "ops": [ { "op": "sum", "inputs": "all" } ],
                "outputs": [ {"op": "sum"} ]
            }
        ]
    }"#;

    fn service_registry() -> Arc<OpRegistry> {
        let mut r = OpRegistry::new();
        r.register_cpu("double", 1, |args: &[Value]| {
            Ok(vec![Value::Scalar(args[0].as_scalar()? * 2.0)])
        })
        .unwrap();
        r.register_cpu("sum", 1, |args: &[Value]| {
            let mut s = 0.0;
            for a in args {
                s += a.as_scalar()?;
            }
            Ok(vec![Value::Scalar(s)])
        })
        .unwrap();
        Arc::new(r)
    }

    #[test]
    fn service_mode_submissions_run_over_tcp() {
        let table = JobTable::new(service_registry(), 4, AssignPolicy::default(), 4, 8);
        let server = ManagerServer::bind("127.0.0.1:0", table.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve());

        let accepted = submit_job(&addr, "alice", SERVICE_WF, 2).unwrap();
        assert_eq!(accepted.tenant, "alice");
        assert!(accepted.job >= 1);
        assert_eq!(accepted.priority, 2);

        // workers resolve the workflow behind a job id over the wire
        let (tenant, json) = fetch_job_spec(&addr, accepted.job).unwrap();
        assert_eq!(tenant, "alice");
        assert!(json.contains("double"));
        assert!(fetch_job_spec(&addr, 999).is_err());

        // one remote worker that understands the Idle poll-again marker
        let remote = RemoteManager::connect(&addr).unwrap();
        let worker = std::thread::spawn(move || loop {
            let req = WorkRequest { capacity: 2, worker: 1, ..Default::default() };
            let batch = WorkSource::request_work(&remote, &req);
            if batch.idle {
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            if batch.assignments.is_empty() {
                return; // real shutdown, not an idle lull
            }
            for a in batch.assignments {
                let out = if a.needs_chunk {
                    // per-chunk stage: payload is Scalar(chunk), doubled
                    Value::Scalar(a.chunk as f32 * 2.0)
                } else {
                    let mut s = 0.0;
                    for v in &a.inputs {
                        s += v.as_scalar().unwrap();
                    }
                    Value::Scalar(s)
                };
                remote.complete(a.instance_id, vec![out]);
            }
        });

        // poll the status API until the job reports Done
        let mut state = String::new();
        for _ in 0..2000 {
            let rows = job_reports(&addr, accepted.job).unwrap();
            state.clone_from(&rows[0].state);
            if state == "Done" {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(state, "Done");
        // chunks 0..4 doubled then summed: 0 + 2 + 4 + 6
        assert_eq!(
            table.reduce_outputs(accepted.job, "total"),
            Some(vec![Value::Scalar(12.0)])
        );
        // cancelling a finished job is rejected through the Fail reply
        assert!(cancel_job(&addr, accepted.job).is_err());

        table.shutdown();
        worker.join().unwrap();
        srv.join().unwrap().unwrap();
    }
}
