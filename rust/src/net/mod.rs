//! Distributed Manager/Worker over TCP (the MPI substitute).
//!
//! The paper runs the Manager and Workers as MPI processes; MPI is not
//! available here, so the same demand-driven window protocol (paper
//! §III-B) runs over two TCP connections per Worker:
//!
//! * a **work channel** — the Worker's requester sends `Request{capacity,
//!   worker, staged-chunk deltas, prefetch budget}` and blocks until the
//!   Manager answers `Assign{assignments, prefetch hints}` (empty =
//!   workflow complete, shut down); in staged mode assignments defer the
//!   chunk payload to the worker's own chunk source, so tiles never cross
//!   the wire;
//! * a **completion channel** — the Worker's completer streams
//!   `Complete{instance, outputs}` messages back.
//!
//! Splitting the channels lets requesting overlap completing exactly like
//! the in-process Worker (worker.rs); message framing is length-prefixed
//! binary (`proto`).

pub mod proto;

use crate::coordinator::manager::{Manager, WorkBatch, WorkRequest, WorkSource};
use crate::runtime::sync::{self, Mutex};
use crate::{Error, Result};
use proto::Message;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Serve an in-process [`Manager`] to remote Workers.  Returns once the
/// workflow completes and all workers disconnected.
pub struct ManagerServer {
    listener: TcpListener,
    manager: Arc<Manager>,
    stop: Arc<AtomicBool>,
}

impl ManagerServer {
    pub fn bind(addr: &str, manager: Arc<Manager>) -> Result<Self> {
        let listener = TcpListener::bind(addr).map_err(|e| Error::Net(e.to_string()))?;
        Ok(ManagerServer { listener, manager, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> String {
        self.listener.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Accept-and-serve loop.  Spawns one thread per connection; exits when
    /// the workflow finishes (detected via Manager progress after each
    /// serve thread ends) or `stop_handle` is set.
    pub fn serve(&self, expected_workers: usize) -> Result<()> {
        let mut handles = Vec::new();
        // Expect 2 connections per worker (work + completion channels).
        for _ in 0..expected_workers * 2 {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let (stream, _) = self.listener.accept().map_err(|e| Error::Net(e.to_string()))?;
            let mgr = self.manager.clone();
            handles.push(std::thread::spawn(move || serve_connection(stream, mgr)));
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }
}

fn serve_connection(stream: TcpStream, mgr: Arc<Manager>) {
    // leases handed out on this connection; if the worker dies (EOF or
    // protocol error) before completing them, they are re-issued to the
    // surviving workers — the fault-tolerance path.
    let mut leases: Vec<u64> = Vec::new();
    let mut worker_id = 0u64;
    let result = serve_connection_inner(stream, &mgr, &mut leases, &mut worker_id);
    let requeued = mgr.requeue_stale(&leases);
    // the work channel closed: whatever this worker had staged is gone —
    // purge it from the catalog so its chunks go back to cold instead of
    // being "stolen" from a ghost for the rest of the run
    mgr.purge_worker(worker_id);
    if let Err(e) = result {
        if requeued > 0 {
            eprintln!("htap manager: worker lost ({e}); re-issued {requeued} stage instances");
        }
    }
}

fn serve_connection_inner(
    stream: TcpStream,
    mgr: &Arc<Manager>,
    leases: &mut Vec<u64>,
    worker_id: &mut u64,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| Error::Net(e.to_string()))?);
    let mut writer = BufWriter::new(stream);
    // one frame buffer per connection: tensor frames encode into it with a
    // single bulk copy and its capacity is reused for the connection's life
    let mut scratch: Vec<u8> = Vec::new();
    loop {
        let msg = match proto::read_message(&mut reader) {
            Ok(m) => m,
            Err(Error::Net(ref e)) if e == "eof" => return Ok(()),
            Err(e) => return Err(e),
        };
        match msg {
            Message::Request {
                capacity,
                worker,
                prefetch_budget,
                staged_add,
                staged_drop,
                demoted,
            } => {
                *worker_id = worker;
                let req = WorkRequest {
                    capacity: capacity.max(1) as usize,
                    worker,
                    staged_add,
                    staged_drop,
                    demoted,
                    prefetch_budget: prefetch_budget as usize,
                };
                let batch = mgr.request_work(&req);
                leases.extend(batch.assignments.iter().map(|a| a.instance_id));
                proto::write_message_buf(
                    &mut writer,
                    &Message::Assign {
                        assignments: batch.assignments,
                        prefetch: batch.prefetch,
                        replicate: batch.replicate,
                    },
                    &mut scratch,
                )?;
            }
            Message::Complete { instance, outputs } => {
                mgr.complete(instance, outputs);
                // completion channel is one-way; no ack needed
            }
            Message::Fail { msg } => {
                mgr.fail(msg);
            }
            other => {
                return Err(Error::Net(format!("unexpected message {other:?} on server")));
            }
        }
    }
}

/// Client-side [`WorkSource`] speaking the protocol over two sockets.
/// Each channel owns a reusable frame buffer — the completion channel
/// ships every stage output tensor, so per-frame allocation matters.
pub struct RemoteManager {
    work: Mutex<(BufReader<TcpStream>, BufWriter<TcpStream>, Vec<u8>)>,
    completion: Mutex<(BufWriter<TcpStream>, Vec<u8>)>,
}

impl RemoteManager {
    pub fn connect(addr: &str) -> Result<Self> {
        let work = TcpStream::connect(addr).map_err(|e| Error::Net(e.to_string()))?;
        work.set_nodelay(true).ok();
        let completion = TcpStream::connect(addr).map_err(|e| Error::Net(e.to_string()))?;
        completion.set_nodelay(true).ok();
        let wr = work.try_clone().map_err(|e| Error::Net(e.to_string()))?;
        Ok(RemoteManager {
            work: Mutex::new((BufReader::new(work), BufWriter::new(wr), Vec::new())),
            completion: Mutex::new((BufWriter::new(completion), Vec::new())),
        })
    }
}

impl WorkSource for RemoteManager {
    fn request_work(&self, req: &WorkRequest) -> WorkBatch {
        // a poisoned channel means a frame writer panicked mid-stream: the
        // connection state is unusable, so report "workflow over" and let
        // the worker wind down instead of cascading the panic
        let Ok(mut chan) = sync::lock_or_poisoned(&self.work) else {
            return WorkBatch::default();
        };
        let (reader, writer, scratch) = &mut *chan;
        let msg = Message::Request {
            capacity: req.capacity as u32,
            worker: req.worker,
            prefetch_budget: req.prefetch_budget as u32,
            staged_add: req.staged_add.clone(),
            staged_drop: req.staged_drop.clone(),
            demoted: req.demoted.clone(),
        };
        if proto::write_message_buf(writer, &msg, scratch).is_err() {
            return WorkBatch::default();
        }
        match proto::read_message(reader) {
            Ok(Message::Assign { assignments, prefetch, replicate }) => {
                WorkBatch { assignments, prefetch, replicate }
            }
            _ => WorkBatch::default(),
        }
    }

    fn complete(&self, instance_id: u64, outputs: Vec<crate::runtime::Value>) {
        // poisoned → drop the completion; the manager's fault-tolerance
        // path re-issues the lease when the connection dies
        let Ok(mut chan) = sync::lock_or_poisoned(&self.completion) else {
            return;
        };
        let (writer, scratch) = &mut *chan;
        let _ = proto::write_message_buf(
            writer,
            &Message::Complete { instance: instance_id, outputs },
            scratch,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{OpRegistry, StageKind, Workflow, WorkflowBuilder};
    use crate::runtime::Value;

    fn tiny_workflow() -> Arc<Workflow> {
        let mut reg = OpRegistry::new();
        reg.register_cpu("double", 1, |args: &[Value]| {
            Ok(vec![Value::Scalar(args[0].as_scalar()? * 2.0)])
        })
        .unwrap();
        let mut wb = WorkflowBuilder::new("net-test", reg);
        let mut s = wb.stage("double", StageKind::PerChunk);
        let chunk = s.input_chunk();
        let d = s.add_op("double", &[chunk]).unwrap();
        s.export(d.out()).unwrap();
        wb.add_stage(s).unwrap();
        Arc::new(wb.build().unwrap())
    }

    #[test]
    fn remote_protocol_round_trip() {
        let wf = tiny_workflow();
        let loader: crate::coordinator::ChunkLoader =
            Arc::new(|c| Ok(vec![Value::Scalar(c as f32)]));
        let mgr = Manager::new(wf, loader, 5).unwrap();
        let server = ManagerServer::bind("127.0.0.1:0", mgr.clone()).unwrap();
        let addr = server.local_addr();
        let srv = std::thread::spawn(move || server.serve(1));

        let remote = RemoteManager::connect(&addr).unwrap();
        let mut executed = 0;
        loop {
            let batch = remote.request(2);
            if batch.is_empty() {
                break;
            }
            for a in batch {
                let v = a.inputs[0].as_scalar().unwrap();
                remote.complete(a.instance_id, vec![Value::Scalar(v * 2.0)]);
                executed += 1;
            }
        }
        assert_eq!(executed, 5);
        drop(remote);
        srv.join().unwrap().unwrap();
        let (done, total) = mgr.progress();
        assert_eq!(done, total);
        assert!(mgr.error().is_none());
    }
}
