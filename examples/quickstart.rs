//! Quickstart: build the WSI workflow with the typed `WorkflowBuilder` +
//! `OpRegistry` API, run it on a few synthetic tiles with the hybrid
//! coordinator (CPU threads + a PJRT "GPU" device), print the execution
//! profile.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! (Without `make artifacts` every operation runs on its CPU member.)

use htap::app::{build_workflow, stage_bindings, AppParams};
use htap::config::RunConfig;
use htap::coordinator::run_local;
use htap::data::{SynthConfig, TileStore};
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let tile_size = 64;
    let n_tiles = 8;

    // 1. describe the analysis as a hierarchical workflow (paper Fig. 1/2).
    //    `build_workflow` assembles it through the typed builder: every op
    //    comes from `htap::app::registry()` with its function variant and
    //    calibrated profile attached, and all wiring is validated eagerly.
    let params = AppParams::for_tile_size(tile_size);
    let workflow = Arc::new(build_workflow(&params, /*with_classification=*/ true));
    println!(
        "workflow '{}': {} stages, {} fine-grain ops",
        workflow.name,
        workflow.stages.len(),
        workflow.total_ops()
    );

    // 2. a data source: synthetic H&E tiles
    let store = Arc::new(TileStore::new(SynthConfig::for_tile_size(tile_size, 42), n_tiles));

    // 3. run: Manager + Worker with 2 CPU threads and 1 accelerator thread
    let cfg = RunConfig { tile_size, n_tiles, cpu_workers: 2, gpu_workers: 1, ..Default::default() };
    let outcome = run_local(workflow, store.loader(), n_tiles, cfg, stage_bindings())?;

    // 4. results — Reduce-stage outputs are looked up by stage *name*
    let report = outcome.metrics;
    println!("\n{}", report.profile_table());
    println!("wall time: {:?} ({:.2} tiles/s)", report.wall, n_tiles as f64 / report.wall.as_secs_f64());
    if let Some(cls) = outcome.manager.reduce_outputs("classification") {
        let assign = cls[0].as_tensor()?;
        println!("k-means tile clusters: {:?}", assign.data());
    }
    Ok(())
}
