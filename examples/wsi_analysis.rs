//! End-to-end driver (the EXPERIMENTS.md validation run): synthesize a
//! whole-slide image's worth of tiles, run the full hierarchical pipeline
//! (segmentation -> features -> k-means classification) through the hybrid
//! coordinator with PATS + DL + prefetching, and report the paper's
//! headline metric (tiles/second) plus analysis outputs.
//!
//!     make artifacts && cargo run --release --example wsi_analysis [n_tiles] [policy]

use htap::app::{build_workflow, stage_bindings, AppParams};
use htap::config::{Policy, RunConfig};
use htap::coordinator::run_local;
use htap::data::{SynthConfig, TileStore};
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_tiles: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let policy = std::env::args()
        .nth(2)
        .map(|s| Policy::parse(&s))
        .transpose()?
        .unwrap_or(Policy::Pats);
    let tile_size = 64;

    println!("=== WSI analysis: {n_tiles} synthetic {tile_size}x{tile_size} tiles, policy {} ===", policy.name());
    let params = AppParams::for_tile_size(tile_size);
    let workflow = Arc::new(build_workflow(&params, true));
    // ~15% of raw tiles are background-only and discarded up front, like
    // the paper's preprocessing
    let raw = (n_tiles as f32 / 0.85) as usize;
    let store = Arc::new(
        TileStore::new(SynthConfig::for_tile_size(tile_size, 11), raw)
            .with_background_fraction(0.15, 5),
    );
    let tissue = store.tissue_chunks();
    let n_run = tissue.len().min(n_tiles);
    println!("generated {raw} raw tiles; {} tissue tiles after background discard; running {n_run}", tissue.len());

    let cfg = RunConfig {
        tile_size,
        n_tiles: n_run,
        cpu_workers: 2,
        gpu_workers: 1,
        policy,
        window: 6,
        ..Default::default()
    };
    let outcome = run_local(workflow, store.loader(), n_run, cfg, stage_bindings())?;

    let report = outcome.metrics;
    println!("\n--- execution profile (paper Fig. 10 analogue) ---");
    println!("{}", report.profile_table());
    let secs = report.wall.as_secs_f64();
    println!("wall time: {secs:.2}s  => {:.2} tiles/s on this machine", n_run as f64 / secs);
    let up: u64 = report.ops.iter().map(|o| o.upload_bytes).sum();
    let down: u64 = report.ops.iter().map(|o| o.download_bytes).sum();
    println!("host->device {:.1} MiB, device->host {:.1} MiB", up as f64 / 1048576.0, down as f64 / 1048576.0);

    if let Some(cls) = outcome.manager.reduce_outputs("classification") {
        let assign = cls[0].as_tensor()?;
        let mut counts = [0usize; 3];
        for &a in assign.data() {
            counts[a as usize] += 1;
        }
        println!("\nclassification (k-means over tile feature vectors): cluster sizes {counts:?}");
    }
    println!("\npaper headline at scale: see `cargo bench --bench fig14_scaling` (~150 tiles/s, 100 nodes)");
    Ok(())
}
