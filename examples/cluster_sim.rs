//! Cluster-scale simulation: reproduce the paper's headline run — 36,848
//! tiles on 8..100 hybrid nodes (Fig. 14) — with the calibrated
//! discrete-event simulator driving the *production* scheduler code.
//!
//!     cargo run --release --example cluster_sim [n_tiles]

use htap::sim::experiments::fig14;

fn main() {
    let n_tiles: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(36_848);
    println!("strong scaling, {n_tiles} tiles (paper: 340 WSIs = 36,848 4Kx4K tiles)\n");
    println!(
        "{:>6} {:>12} {:>14} {:>10} {:>12} {:>14}",
        "nodes", "FCFS (s)", "PATS+DL+PF (s)", "tiles/s", "efficiency", "compute-only"
    );
    for r in fig14(&[8, 16, 32, 50, 75, 100], n_tiles) {
        println!(
            "{:>6} {:>12.1} {:>14.1} {:>10.1} {:>11.1}% {:>13.1}%",
            r.nodes,
            r.fcfs_secs,
            r.pats_all_secs,
            r.tiles_per_second,
            r.efficiency * 100.0,
            r.compute_efficiency * 100.0
        );
    }
    println!("\npaper reference: ~150 tiles/s at 100 nodes, 77% efficiency (93% compute-only)");
}
