//! Distributed mode demo: a Manager served over TCP and two Worker
//! processes' worth of Workers (in threads here so the example is
//! self-contained; `htap manager` / `htap worker` run them as separate
//! processes across machines).
//!
//!     make artifacts && cargo run --release --example distributed

use htap::app::{build_workflow, stage_bindings, AppParams};
use htap::config::RunConfig;
use htap::coordinator::{worker::run_worker, Manager};
use htap::data::{SynthConfig, TileStore};
use htap::metrics::MetricsHub;
use htap::net::{ManagerServer, RemoteManager};
use htap::runtime::ArtifactManifest;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let tile_size = 64;
    let n_tiles = 6;
    let n_workers = 2;

    let params = AppParams::for_tile_size(tile_size);
    let workflow = Arc::new(build_workflow(&params, false));
    let store = Arc::new(TileStore::new(SynthConfig::for_tile_size(tile_size, 3), n_tiles));

    let manager = Manager::new(workflow.clone(), store.loader(), n_tiles)?;
    let server = ManagerServer::bind("127.0.0.1:0", manager.clone())?;
    let addr = server.local_addr();
    println!("manager listening on {addr}");
    let server_thread = std::thread::spawn(move || server.serve(n_workers));

    let mut workers = Vec::new();
    for w in 0..n_workers {
        let addr = addr.clone();
        let workflow = workflow.clone();
        workers.push(std::thread::spawn(move || {
            let source = Arc::new(RemoteManager::connect(&addr).expect("connect"));
            let cfg = RunConfig {
                tile_size,
                n_tiles,
                cpu_workers: 1,
                gpu_workers: 1,
                window: 2,
                ..Default::default()
            };
            let metrics = Arc::new(MetricsHub::new());
            run_worker(
                source,
                workflow,
                cfg,
                Arc::new(ArtifactManifest::discover_or_empty()),
                metrics.clone(),
                stage_bindings(),
            )
            .expect("worker");
            println!("worker {w}: executed {} op instances", metrics.report().total_executed());
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    server_thread.join().unwrap()?;
    let (done, total) = manager.progress();
    println!("workflow complete: {done}/{total} stage instances");
    Ok(())
}
