//! A *non-WSI* workload, end to end, from a JSON description: the
//! convolve → threshold → label → stats "cell-stats" pipeline.
//!
//! This is the proof that the middleware is workload-agnostic: none of the
//! operations below know anything about H&E staining or the paper's
//! pipeline.  The workflow is data (`CELL_STATS_JSON`), loaded against the
//! generic `OpRegistry` and executed by exactly the same Manager / Worker
//! Resource Manager machinery as the WSI app.
//!
//!     cargo run --release --example generic_pipeline [n_tiles]

use htap::app::generic::{generic_registry, CELL_STATS_JSON};
use htap::config::RunConfig;
use htap::coordinator::run_local;
use htap::data::{SynthConfig, TileStore};
use htap::dataflow::workflow_from_str;
use std::collections::HashMap;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_tiles: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let tile_size = 64;

    // 1. the workflow is *data*: parse the JSON description against the
    //    generic op registry (all validation happens here, eagerly)
    let workflow = Arc::new(workflow_from_str(CELL_STATS_JSON, Arc::new(generic_registry()))?);
    println!(
        "workflow '{}': {} stages / {} ops, loaded from JSON",
        workflow.name,
        workflow.stages.len(),
        workflow.total_ops()
    );

    // 2. any chunk source works; reuse the synthetic tile store
    let store = Arc::new(TileStore::new(SynthConfig::for_tile_size(tile_size, 7), n_tiles));

    // 3. run through the same hybrid coordinator as the WSI app
    let cfg = RunConfig { tile_size, n_tiles, cpu_workers: 2, gpu_workers: 1, ..Default::default() };
    let outcome = run_local(workflow, store.loader(), n_tiles, cfg, HashMap::new())?;

    let (done, total) = outcome.manager.progress();
    println!("completed {done}/{total} stage instances");
    println!("\n{}", outcome.metrics.profile_table());

    // 4. the Reduce stage's aggregate, by stage name
    let agg = outcome
        .manager
        .reduce_outputs("aggregate")
        .expect("aggregate stage completed");
    let stats = agg[0].as_tensor()?;
    let d = stats.data();
    println!(
        "\nper-tile means over {n_tiles} tiles: {:.1} regions, {:.1} px mean area, \
         {:.1} px max area, {:.1}% coverage",
        d[0],
        d[1],
        d[2],
        d[3] * 100.0
    );
    Ok(())
}
