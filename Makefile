# htap build entry points.
#
#   make build      — compile the rust crate (release)
#   make test       — tier-1: cargo build --release && cargo test -q
#   make artifacts  — AOT-lower the JAX graphs to artifacts/*.hlo.txt
#   make lint       — clippy -D warnings + rustfmt check
#   make check      — lint + cargo xtask lint/docs + tier-1 tests + model suite
#   make calibrate  — measure op costs on this host -> profiles.json
#   make bench-baseline — record the fig7/8/9 snapshot (BENCH_seed.json)
#   make smoke-distributed — localhost staged Manager + 2 TCP workers

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test artifacts lint check calibrate bench-baseline smoke-distributed clean

build:
	cd rust && $(CARGO) build --release

test: build
	cd rust && $(CARGO) test -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

lint:
	cd rust && $(CARGO) clippy -- -D warnings
	cd rust && $(CARGO) fmt --check

# The full pre-merge gate: style lints, the repo's own lock-discipline
# lint (docs/analysis.md), the docs drift check (dead links + CLI flag
# coverage in docs/operations.md), tier-1 tests, the xtask unit tests,
# and the deterministic interleaving suite.
check: lint
	cd rust && $(CARGO) xtask lint
	cd rust && $(CARGO) xtask docs
	cd rust && $(CARGO) test -q
	cd rust && $(CARGO) test -q -p xtask
	cd rust && $(CARGO) test -q --features htap-model --test model_wrm

calibrate:
	cd rust && $(CARGO) run --release -- calibrate --out ../profiles.json

bench-baseline:
	./scripts/bench_baseline.sh BENCH_seed.json

smoke-distributed: build
	./scripts/smoke_distributed.sh
	HTAP_NO_LOCALITY=1 ./scripts/smoke_distributed.sh 47132

clean:
	cd rust && $(CARGO) clean
	rm -rf artifacts
