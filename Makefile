# htap build entry points.
#
#   make build      — compile the rust crate (release)
#   make test       — tier-1: cargo build --release && cargo test -q
#   make artifacts  — AOT-lower the JAX graphs to artifacts/*.hlo.txt
#   make lint       — clippy -D warnings + rustfmt check

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test artifacts lint clean

build:
	cd rust && $(CARGO) build --release

test: build
	cd rust && $(CARGO) test -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

lint:
	cd rust && $(CARGO) clippy -- -D warnings
	cd rust && $(CARGO) fmt --check

clean:
	cd rust && $(CARGO) clean
	rm -rf artifacts
