#!/usr/bin/env bash
# Localhost distributed smoke: ManagerServer + 2 TCP workers over the
# staged protocol, exercising the staging cache + prefetcher and the
# locality-aware assignment policy.
#
#   scripts/smoke_distributed.sh [port]            # locality on (default)
#   HTAP_NO_LOCALITY=1 scripts/smoke_distributed.sh [port]   # control run
#
# Workers reconstruct the same synthetic dataset locally (same seed /
# tile size / count as the manager), with a nonzero --read-latency-ms so
# the prefetcher has something to hide; the manager prints the locality
# hit/cold/steal counters on completion.
set -euo pipefail

cd "$(dirname "$0")/.."
port="${1:-47131}"
tiles=8
tile_size=32
locality_flag=""
label="locality on"
if [[ "${HTAP_NO_LOCALITY:-0}" != "0" ]]; then
    locality_flag="--no-locality"
    label="locality off"
fi

bin=rust/target/release/htap
if [[ ! -x "$bin" ]]; then
    (cd rust && cargo build --release --locked)
fi

echo "=== staged distributed smoke ($label, port $port) ===" >&2
log="$(mktemp -d)"
trap 'rm -rf "$log"; kill $(jobs -p) 2>/dev/null || true' EXIT

"$bin" manager --listen "127.0.0.1:$port" --tiles "$tiles" \
    --tile-size "$tile_size" --workers 2 $locality_flag \
    >"$log/manager.txt" 2>&1 &
manager_pid=$!
sleep 1

worker_pids=()
for w in 1 2; do
    # worker 1 runs the tiered store: a one-chunk memory tier backed by a
    # local-disk spill dir, so evictions demote instead of dropping
    spill_args=()
    if [[ "$w" == "1" ]]; then
        spill_args=(--staging-cap 1 --spill-dir "$log/spill" --spill-cap 16)
    fi
    "$bin" worker --connect "127.0.0.1:$port" --worker-id "$w" \
        --tiles "$tiles" --tile-size "$tile_size" --cpus 1 --gpus 0 \
        --window 2 --chunk-source synth --prefetch-depth 2 \
        --read-latency-ms 5 "${spill_args[@]}" >"$log/worker$w.txt" 2>&1 &
    worker_pids+=($!)
done

rc=0
for pid in "${worker_pids[@]}"; do
    wait "$pid" || rc=$?
done
wait "$manager_pid" || rc=$?

cat "$log/manager.txt"
echo "--- worker 1 ---" && cat "$log/worker1.txt"
echo "--- worker 2 ---" && cat "$log/worker2.txt"

if [[ $rc -ne 0 ]]; then
    echo "distributed smoke FAILED (rc=$rc)" >&2
    exit "$rc"
fi
grep -q "workflow complete: 16/16" "$log/manager.txt" || {
    echo "manager did not complete all stage instances" >&2
    exit 1
}
grep -q "^locality:" "$log/manager.txt" || {
    echo "manager did not report locality counters" >&2
    exit 1
}
# staging must actually engage on the workers
grep -q "staging:" "$log/worker1.txt" || {
    echo "worker 1 reported no staging counters" >&2
    exit 1
}
# the spill-enabled worker's one-chunk memory tier must have demoted to
# its local-disk tier (it stages more than one chunk per run)
grep -Eq "tiers: [1-9][0-9]* demoted" "$log/worker1.txt" || {
    echo "worker 1 never demoted to its spill tier" >&2
    exit 1
}
echo "distributed smoke OK ($label)"
