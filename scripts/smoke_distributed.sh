#!/usr/bin/env bash
# Localhost distributed smoke: ManagerServer + 2 TCP workers over the
# staged protocol, exercising the staging cache + prefetcher and the
# locality-aware assignment policy — then a kill-and-rejoin phase that
# SIGKILLs one worker mid-run, lets a replacement join the live manager,
# and checks the reduce outputs are bit-identical to a no-fault run.
#
#   scripts/smoke_distributed.sh [port]            # locality on (default)
#   HTAP_NO_LOCALITY=1 scripts/smoke_distributed.sh [port]   # control run
#
# Workers reconstruct the same synthetic dataset locally (same seed /
# tile size / count as the manager), with a nonzero --read-latency-ms so
# the prefetcher has something to hide; the manager prints the locality
# hit/cold/steal counters on completion.
set -euo pipefail

cd "$(dirname "$0")/.."
port="${1:-47131}"
tiles=8
tile_size=32
locality_flag=""
label="locality on"
if [[ "${HTAP_NO_LOCALITY:-0}" != "0" ]]; then
    locality_flag="--no-locality"
    label="locality off"
fi

bin=rust/target/release/htap
if [[ ! -x "$bin" ]]; then
    (cd rust && cargo build --release --locked)
fi

echo "=== staged distributed smoke ($label, port $port) ===" >&2
log="$(mktemp -d)"
trap 'rm -rf "$log"; kill $(jobs -p) 2>/dev/null || true' EXIT

"$bin" manager --listen "127.0.0.1:$port" --tiles "$tiles" \
    --tile-size "$tile_size" --workers 2 $locality_flag \
    >"$log/manager.txt" 2>&1 &
manager_pid=$!
sleep 1

worker_pids=()
for w in 1 2; do
    # worker 1 runs the tiered store: a one-chunk memory tier backed by a
    # local-disk spill dir, so evictions demote instead of dropping
    spill_args=()
    if [[ "$w" == "1" ]]; then
        spill_args=(--staging-cap 1 --spill-dir "$log/spill" --spill-cap 16)
    fi
    "$bin" worker --connect "127.0.0.1:$port" --worker-id "$w" \
        --tiles "$tiles" --tile-size "$tile_size" --cpus 1 --gpus 0 \
        --window 2 --chunk-source synth --prefetch-depth 2 \
        --read-latency-ms 5 "${spill_args[@]}" >"$log/worker$w.txt" 2>&1 &
    worker_pids+=($!)
done

rc=0
for pid in "${worker_pids[@]}"; do
    wait "$pid" || rc=$?
done
wait "$manager_pid" || rc=$?

cat "$log/manager.txt"
echo "--- worker 1 ---" && cat "$log/worker1.txt"
echo "--- worker 2 ---" && cat "$log/worker2.txt"

if [[ $rc -ne 0 ]]; then
    echo "distributed smoke FAILED (rc=$rc)" >&2
    exit "$rc"
fi
grep -q "workflow complete: 16/16" "$log/manager.txt" || {
    echo "manager did not complete all stage instances" >&2
    exit 1
}
grep -q "^locality:" "$log/manager.txt" || {
    echo "manager did not report locality counters" >&2
    exit 1
}
# staging must actually engage on the workers
grep -q "staging:" "$log/worker1.txt" || {
    echo "worker 1 reported no staging counters" >&2
    exit 1
}
# the spill-enabled worker's one-chunk memory tier must have demoted to
# its local-disk tier (it stages more than one chunk per run)
grep -Eq "tiers: [1-9][0-9]* demoted" "$log/worker1.txt" || {
    echo "worker 1 never demoted to its spill tier" >&2
    exit 1
}
echo "distributed smoke OK ($label)"

# --- kill-and-rejoin phase -------------------------------------------------
# A worker is SIGKILLed while it holds live leases; its work re-executes on
# the survivors, a replacement worker joins the *running* manager, and the
# reduce outputs must be bit-identical to a no-fault run of the same
# workflow (examples/cell_stats.json ends in an `aggregate` reduce stage).
echo "=== kill-and-rejoin phase (port $((port + 100))) ===" >&2
kr_tiles=24
wf=examples/cell_stats.json
common=(--workflow "$wf" --tiles "$kr_tiles" --tile-size "$tile_size")

# no-fault baseline: one worker, capture the reduce output lines
base_port=$((port + 100))
"$bin" manager --listen "127.0.0.1:$base_port" "${common[@]}" --workers 1 \
    >"$log/mgr-base.txt" 2>&1 &
base_mgr=$!
sleep 1
"$bin" worker --connect "127.0.0.1:$base_port" --worker-id 1 "${common[@]}" \
    --cpus 1 --gpus 0 --window 2 --chunk-source synth --read-latency-ms 2 \
    >"$log/worker-base.txt" 2>&1
wait "$base_mgr"
grep "^reduce '" "$log/mgr-base.txt" >"$log/reduce-base.txt"
[[ -s "$log/reduce-base.txt" ]] || {
    echo "baseline run produced no reduce outputs" >&2
    exit 1
}

# faulty run: the victim hoards a wide window of slow leases, gets
# SIGKILLed mid-run, and a replacement joins the live manager
kill_port=$((port + 101))
"$bin" manager --listen "127.0.0.1:$kill_port" "${common[@]}" --workers 2 \
    >"$log/mgr-kill.txt" 2>&1 &
kill_mgr=$!
sleep 1
"$bin" worker --connect "127.0.0.1:$kill_port" --worker-id 2 "${common[@]}" \
    --cpus 1 --gpus 0 --window 4 --chunk-source synth --read-latency-ms 300 \
    --heartbeat-ms 100 --lease-ms 400 >"$log/worker-victim.txt" 2>&1 &
victim=$!
"$bin" worker --connect "127.0.0.1:$kill_port" --worker-id 1 "${common[@]}" \
    --cpus 1 --gpus 0 --window 2 --chunk-source synth --read-latency-ms 50 \
    >"$log/worker-healthy.txt" 2>&1 &
healthy=$!
sleep 2
kill -9 "$victim" 2>/dev/null || true
wait "$victim" 2>/dev/null || true
"$bin" worker --connect "127.0.0.1:$kill_port" --worker-id 3 "${common[@]}" \
    --cpus 1 --gpus 0 --window 2 --chunk-source synth --read-latency-ms 5 \
    >"$log/worker-rejoin.txt" 2>&1 &
rejoin=$!

rc=0
wait "$healthy" || rc=$?
wait "$rejoin" || rc=$?
wait "$kill_mgr" || rc=$?
cat "$log/mgr-kill.txt"
if [[ $rc -ne 0 ]]; then
    echo "kill-and-rejoin phase FAILED (rc=$rc)" >&2
    exit "$rc"
fi
grep -q "workflow complete: $((kr_tiles + 1))/$((kr_tiles + 1))" "$log/mgr-kill.txt" || {
    echo "manager did not complete the workflow after the worker crash" >&2
    exit 1
}
grep "^reduce '" "$log/mgr-kill.txt" >"$log/reduce-kill.txt"
cmp -s "$log/reduce-base.txt" "$log/reduce-kill.txt" || {
    echo "reduce outputs diverged after the crash:" >&2
    diff "$log/reduce-base.txt" "$log/reduce-kill.txt" >&2 || true
    exit 1
}
echo "kill-and-rejoin smoke OK (reduce outputs bit-identical)"
