#!/usr/bin/env bash
# Localhost distributed smoke: ManagerServer + 2 TCP workers over the
# staged protocol, exercising the staging cache + prefetcher and the
# locality-aware assignment policy — then a kill-and-rejoin phase that
# SIGKILLs one worker mid-run, lets a replacement join the live manager,
# and checks the reduce outputs are bit-identical to a no-fault run.
#
#   scripts/smoke_distributed.sh [port]            # locality on (default)
#   HTAP_NO_LOCALITY=1 scripts/smoke_distributed.sh [port]   # control run
#
# Workers reconstruct the same synthetic dataset locally (same seed /
# tile size / count as the manager), with a nonzero --read-latency-ms so
# the prefetcher has something to hide; the manager prints the locality
# hit/cold/steal counters on completion.
set -euo pipefail

cd "$(dirname "$0")/.."
port="${1:-47131}"
tiles=8
tile_size=32
locality_flag=""
label="locality on"
if [[ "${HTAP_NO_LOCALITY:-0}" != "0" ]]; then
    locality_flag="--no-locality"
    label="locality off"
fi

bin=rust/target/release/htap
if [[ ! -x "$bin" ]]; then
    (cd rust && cargo build --release --locked)
fi

echo "=== staged distributed smoke ($label, port $port) ===" >&2
log="$(mktemp -d)"
trap 'rm -rf "$log"; kill $(jobs -p) 2>/dev/null || true' EXIT

"$bin" manager --listen "127.0.0.1:$port" --tiles "$tiles" \
    --tile-size "$tile_size" --workers 2 $locality_flag \
    --trace-out "$log/trace.json" \
    >"$log/manager.txt" 2>&1 &
manager_pid=$!
sleep 1

worker_pids=()
for w in 1 2; do
    # worker 1 runs the tiered store: a one-chunk memory tier backed by a
    # local-disk spill dir, so evictions demote instead of dropping
    spill_args=()
    if [[ "$w" == "1" ]]; then
        spill_args=(--staging-cap 1 --spill-dir "$log/spill" --spill-cap 16)
    fi
    "$bin" worker --connect "127.0.0.1:$port" --worker-id "$w" \
        --tiles "$tiles" --tile-size "$tile_size" --cpus 1 --gpus 0 \
        --window 2 --chunk-source synth --prefetch-depth 2 \
        --read-latency-ms 5 "${spill_args[@]}" >"$log/worker$w.txt" 2>&1 &
    worker_pids+=($!)
done

rc=0
for pid in "${worker_pids[@]}"; do
    wait "$pid" || rc=$?
done
wait "$manager_pid" || rc=$?

cat "$log/manager.txt"
echo "--- worker 1 ---" && cat "$log/worker1.txt"
echo "--- worker 2 ---" && cat "$log/worker2.txt"

if [[ $rc -ne 0 ]]; then
    echo "distributed smoke FAILED (rc=$rc)" >&2
    exit "$rc"
fi
grep -q "workflow complete: 16/16" "$log/manager.txt" || {
    echo "manager did not complete all stage instances" >&2
    exit 1
}
grep -q "^locality:" "$log/manager.txt" || {
    echo "manager did not report locality counters" >&2
    exit 1
}
# staging must actually engage on the workers
grep -q "staging:" "$log/worker1.txt" || {
    echo "worker 1 reported no staging counters" >&2
    exit 1
}
# the spill-enabled worker's one-chunk memory tier must have demoted to
# its local-disk tier (it stages more than one chunk per run)
grep -Eq "tiers: [1-9][0-9]* demoted" "$log/worker1.txt" || {
    echo "worker 1 never demoted to its spill tier" >&2
    exit 1
}
# the manager-merged trace must contain execution events shipped over the
# heartbeat channel from BOTH workers, not just the manager's own records
python3 - "$log/trace.json.jsonl" <<'EOF'
import json, sys
workers = set()
ops = 0
for line in open(sys.argv[1]):
    ev = json.loads(line)
    if ev["kind"] == "op-end":
        workers.add(ev["worker"])
        ops += 1
assert workers >= {1, 2}, f"trace missing a worker's op spans: {sorted(workers)}"
print(f"merged trace OK: {ops} op spans from workers {sorted(workers)}")
EOF
echo "distributed smoke OK ($label)"

# --- kill-and-rejoin phase -------------------------------------------------
# A worker is SIGKILLed while it holds live leases; its work re-executes on
# the survivors, a replacement worker joins the *running* manager, and the
# reduce outputs must be bit-identical to a no-fault run of the same
# workflow (examples/cell_stats.json ends in an `aggregate` reduce stage).
echo "=== kill-and-rejoin phase (port $((port + 100))) ===" >&2
kr_tiles=24
wf=examples/cell_stats.json
common=(--workflow "$wf" --tiles "$kr_tiles" --tile-size "$tile_size")

# no-fault baseline: one worker, capture the reduce output lines
base_port=$((port + 100))
"$bin" manager --listen "127.0.0.1:$base_port" "${common[@]}" --workers 1 \
    >"$log/mgr-base.txt" 2>&1 &
base_mgr=$!
sleep 1
"$bin" worker --connect "127.0.0.1:$base_port" --worker-id 1 "${common[@]}" \
    --cpus 1 --gpus 0 --window 2 --chunk-source synth --read-latency-ms 2 \
    >"$log/worker-base.txt" 2>&1
wait "$base_mgr"
grep "^reduce '" "$log/mgr-base.txt" >"$log/reduce-base.txt"
[[ -s "$log/reduce-base.txt" ]] || {
    echo "baseline run produced no reduce outputs" >&2
    exit 1
}

# faulty run: the victim hoards a wide window of slow leases, gets
# SIGKILLed mid-run, and a replacement joins the live manager
kill_port=$((port + 101))
"$bin" manager --listen "127.0.0.1:$kill_port" "${common[@]}" --workers 2 \
    >"$log/mgr-kill.txt" 2>&1 &
kill_mgr=$!
sleep 1
"$bin" worker --connect "127.0.0.1:$kill_port" --worker-id 2 "${common[@]}" \
    --cpus 1 --gpus 0 --window 4 --chunk-source synth --read-latency-ms 300 \
    --heartbeat-ms 100 --lease-ms 400 >"$log/worker-victim.txt" 2>&1 &
victim=$!
"$bin" worker --connect "127.0.0.1:$kill_port" --worker-id 1 "${common[@]}" \
    --cpus 1 --gpus 0 --window 2 --chunk-source synth --read-latency-ms 50 \
    >"$log/worker-healthy.txt" 2>&1 &
healthy=$!
sleep 2
kill -9 "$victim" 2>/dev/null || true
wait "$victim" 2>/dev/null || true
"$bin" worker --connect "127.0.0.1:$kill_port" --worker-id 3 "${common[@]}" \
    --cpus 1 --gpus 0 --window 2 --chunk-source synth --read-latency-ms 5 \
    >"$log/worker-rejoin.txt" 2>&1 &
rejoin=$!

rc=0
wait "$healthy" || rc=$?
wait "$rejoin" || rc=$?
wait "$kill_mgr" || rc=$?
cat "$log/mgr-kill.txt"
if [[ $rc -ne 0 ]]; then
    echo "kill-and-rejoin phase FAILED (rc=$rc)" >&2
    exit "$rc"
fi
grep -q "workflow complete: $((kr_tiles + 1))/$((kr_tiles + 1))" "$log/mgr-kill.txt" || {
    echo "manager did not complete the workflow after the worker crash" >&2
    exit 1
}
grep "^reduce '" "$log/mgr-kill.txt" >"$log/reduce-kill.txt"
cmp -s "$log/reduce-base.txt" "$log/reduce-kill.txt" || {
    echo "reduce outputs diverged after the crash:" >&2
    diff "$log/reduce-base.txt" "$log/reduce-kill.txt" >&2 || true
    exit 1
}
echo "kill-and-rejoin smoke OK (reduce outputs bit-identical)"

# --- multi-tenant service phase --------------------------------------------
# One `htap serve` daemon, two job-agnostic workers, two tenants submitting
# different workflows with different fair-share weights.  Each job's reduce
# lines (prefixed `job N [tenant] `) must be bit-identical to a single-job
# manager run of the same workflow, and the workers must drain gracefully
# (finish leases, demote to spill, Goodbye, exit 0) on their --drain-on file.
echo "=== multi-tenant service phase (port $((port + 200))) ===" >&2
svc_tiles=8
svc_common=(--tiles "$svc_tiles" --tile-size "$tile_size")

# bob's workflow: an edge-density variant over the same generic op set
edge_wf="$log/edge_stats.json"
cat >"$edge_wf" <<'EOF'
{
    "name": "edge-stats",
    "stages": [
        {
            "name": "edges",
            "kind": "per_chunk",
            "inputs": ["chunk"],
            "ops": [
                { "op": "grayscale",    "inputs": [ {"input": 0} ] },
                { "op": "sobel",        "inputs": [ {"op": "grayscale"} ] },
                { "op": "binarize",     "inputs": [ {"op": "sobel"}, {"param": 96.0} ] },
                { "op": "cc_label",     "inputs": [ {"op": "binarize"} ] },
                { "op": "region_stats", "inputs": [ {"op": "cc_label"} ] }
            ],
            "outputs": [ {"op": "region_stats"} ]
        },
        {
            "name": "aggregate",
            "kind": "reduce",
            "inputs": [ {"stage": "edges", "output": 0} ],
            "ops": [ { "op": "mean_stats", "inputs": "all" } ],
            "outputs": [ {"op": "mean_stats"} ]
        }
    ]
}
EOF

# single-job baselines: one manager + one worker per workflow
cell_port=$((port + 203))
"$bin" manager --listen "127.0.0.1:$cell_port" --workflow examples/cell_stats.json \
    "${svc_common[@]}" --workers 1 >"$log/mgr-cell.txt" 2>&1 &
cell_mgr=$!
sleep 1
"$bin" worker --connect "127.0.0.1:$cell_port" --worker-id 1 \
    --workflow examples/cell_stats.json "${svc_common[@]}" --cpus 1 --gpus 0 \
    --window 2 --chunk-source synth >"$log/worker-cell.txt" 2>&1
wait "$cell_mgr"
grep "^reduce '" "$log/mgr-cell.txt" >"$log/reduce-cell-base.txt"
[[ -s "$log/reduce-cell-base.txt" ]] || {
    echo "cell-stats baseline produced no reduce outputs" >&2
    exit 1
}

edge_port=$((port + 204))
"$bin" manager --listen "127.0.0.1:$edge_port" --workflow "$edge_wf" \
    "${svc_common[@]}" --workers 1 >"$log/mgr-edge.txt" 2>&1 &
edge_mgr=$!
sleep 1
"$bin" worker --connect "127.0.0.1:$edge_port" --worker-id 1 \
    --workflow "$edge_wf" "${svc_common[@]}" --cpus 1 --gpus 0 \
    --window 2 --chunk-source synth >"$log/worker-edge.txt" 2>&1
wait "$edge_mgr"
grep "^reduce '" "$log/mgr-edge.txt" >"$log/reduce-edge-base.txt"
[[ -s "$log/reduce-edge-base.txt" ]] || {
    echo "edge-stats baseline produced no reduce outputs" >&2
    exit 1
}

# the service: job table + checkpointing; workers are job-agnostic (no
# --workflow — they fetch each job's spec over the wire) and drain on file
svc_port=$((port + 200))
"$bin" serve --listen "127.0.0.1:$svc_port" "${svc_common[@]}" --max-jobs 4 \
    --tenant-queue-depth 4 --checkpoint-dir "$log/svc-ckpt" \
    >"$log/serve.txt" 2>&1 &
serve_pid=$!
sleep 1

svc_workers=()
for w in 1 2; do
    rm -f "$log/drain-$w"
    "$bin" worker --connect "127.0.0.1:$svc_port" --worker-id "$w" \
        "${svc_common[@]}" --cpus 1 --gpus 0 --window 2 --chunk-source synth \
        --tenant-quota 16 --drain-on "file:$log/drain-$w" \
        >"$log/worker-s$w.txt" 2>&1 &
    svc_workers+=($!)
done

"$bin" submit --connect "127.0.0.1:$svc_port" --workflow examples/cell_stats.json \
    --tenant alice --priority 1 >"$log/submit1.txt"
"$bin" submit --connect "127.0.0.1:$svc_port" --workflow "$edge_wf" \
    --tenant bob --priority 4 >"$log/submit2.txt"
grep -q "^job 1 \[alice\]" "$log/submit1.txt" || {
    echo "unexpected submit reply:" >&2
    cat "$log/submit1.txt" >&2
    exit 1
}
grep -q "^job 2 \[bob\]" "$log/submit2.txt" || {
    echo "unexpected submit reply:" >&2
    cat "$log/submit2.txt" >&2
    exit 1
}

# poll `htap jobs` until both rows are Done (state is column 3)
for _ in $(seq 1 120); do
    "$bin" jobs --connect "127.0.0.1:$svc_port" >"$log/jobs.txt" 2>&1 || true
    [[ "$(awk '$3 == "Done"' "$log/jobs.txt" | wc -l)" == "2" ]] && break
    sleep 0.5
done
[[ "$(awk '$3 == "Done"' "$log/jobs.txt" | wc -l)" == "2" ]] || {
    echo "service jobs did not complete:" >&2
    cat "$log/jobs.txt" >&2
    cat "$log/serve.txt" >&2
    exit 1
}

# graceful drain: touch the trigger files; both workers must exit 0
touch "$log/drain-1" "$log/drain-2"
svc_rc=0
for pid in "${svc_workers[@]}"; do
    wait "$pid" || svc_rc=$?
done
if [[ $svc_rc -ne 0 ]]; then
    echo "a draining worker exited nonzero (rc=$svc_rc)" >&2
    cat "$log/worker-s1.txt" "$log/worker-s2.txt" >&2
    exit 1
fi
grep -q "drained; demoted" "$log/worker-s1.txt" "$log/worker-s2.txt" || {
    echo "no worker demoted its memory tier on drain" >&2
    exit 1
}

# cancel path: a third job on the now-workerless service cancels cleanly
"$bin" submit --connect "127.0.0.1:$svc_port" --workflow "$edge_wf" \
    --tenant alice --priority 1 >"$log/submit3.txt"
"$bin" cancel --connect "127.0.0.1:$svc_port" --job 3 | grep -q "Cancelled" || {
    echo "cancel did not report Cancelled" >&2
    exit 1
}

# per-tenant reduce lines, stripped of their `job N [tenant] ` prefix, are
# bit-identical to the single-job baselines
sed -nE 's/^job 1 \[alice\] //p' "$log/serve.txt" | grep "^reduce '" \
    >"$log/reduce-cell-svc.txt" || true
sed -nE 's/^job 2 \[bob\] //p' "$log/serve.txt" | grep "^reduce '" \
    >"$log/reduce-edge-svc.txt" || true
cmp -s "$log/reduce-cell-base.txt" "$log/reduce-cell-svc.txt" || {
    echo "alice's service reduce outputs diverged from the single-job run:" >&2
    diff "$log/reduce-cell-base.txt" "$log/reduce-cell-svc.txt" >&2 || true
    exit 1
}
cmp -s "$log/reduce-edge-base.txt" "$log/reduce-edge-svc.txt" || {
    echo "bob's service reduce outputs diverged from the single-job run:" >&2
    diff "$log/reduce-edge-base.txt" "$log/reduce-edge-svc.txt" >&2 || true
    exit 1
}

kill "$serve_pid" 2>/dev/null || true
wait "$serve_pid" 2>/dev/null || true
echo "multi-tenant service smoke OK (2 tenants, reduce outputs bit-identical, graceful drain)"

# --- chaos-failover phase ---------------------------------------------------
# The full robustness stack at once: a fault plan drops 10% of data-plane
# frames and fails the first spill writes, the worker lists both managers
# in --connect, and the primary is SIGKILLed mid-run.  The standby must
# promote after --promote-after-ms of silence, restore the primary's
# checkpoint, absorb the worker's reconnect + replay, and finish with
# reduce outputs bit-identical to the fault-free baseline from the
# kill-and-rejoin phase (same workflow, same tiles).
echo "=== chaos-failover phase (ports $((port + 300))/$((port + 301))) ===" >&2
pri_port=$((port + 300))
sby_port=$((port + 301))
"$bin" manager --listen "127.0.0.1:$pri_port" "${common[@]}" --workers 1 \
    --checkpoint-dir "$log/ha-ckpt" >"$log/mgr-pri.txt" 2>&1 &
pri=$!
"$bin" manager --listen "127.0.0.1:$sby_port" "${common[@]}" --workers 1 \
    --checkpoint-dir "$log/ha-ckpt" \
    --standby --primary "127.0.0.1:$pri_port" --promote-after-ms 1500 \
    >"$log/mgr-sby.txt" 2>&1 &
sby=$!
sleep 1
# frame drops retry in place under the rpc policy; spill-io failures
# degrade the one-chunk memory tier to plain eviction — neither may cost
# correctness.  HTAP_FAULTS (lowest precedence) + --fault-seed keeps the
# chaos reproducible
HTAP_FAULTS='frame-drop=0.1#20,spill-io=1#4' \
"$bin" worker --connect "127.0.0.1:$pri_port,127.0.0.1:$sby_port" --worker-id 1 \
    "${common[@]}" --cpus 1 --gpus 0 --window 2 --chunk-source synth \
    --read-latency-ms 250 --staging-cap 1 --spill-dir "$log/ha-spill" \
    --spill-cap 16 --fault-seed 7 --heartbeat-ms 100 --lease-ms 3000 \
    >"$log/worker-ha.txt" 2>&1 &
ha_worker=$!
# let the primary checkpoint a few seconds of progress, then kill it dead
sleep 3
kill -9 "$pri" 2>/dev/null || true
wait "$pri" 2>/dev/null || true
rc=0
wait "$ha_worker" || rc=$?
wait "$sby" || rc=$?
if [[ $rc -ne 0 ]]; then
    echo "chaos-failover phase FAILED (rc=$rc)" >&2
    cat "$log/mgr-sby.txt" "$log/worker-ha.txt" >&2
    exit "$rc"
fi
grep -q "standby: promoting" "$log/mgr-sby.txt" || {
    echo "the standby never promoted" >&2
    cat "$log/mgr-sby.txt" >&2
    exit 1
}
grep -q "workflow complete: $((kr_tiles + 1))/$((kr_tiles + 1))" "$log/mgr-sby.txt" || {
    echo "the promoted standby did not finish the workflow" >&2
    cat "$log/mgr-sby.txt" >&2
    exit 1
}
grep "^reduce '" "$log/mgr-sby.txt" >"$log/reduce-ha.txt"
cmp -s "$log/reduce-base.txt" "$log/reduce-ha.txt" || {
    echo "reduce outputs diverged across the chaos failover:" >&2
    diff "$log/reduce-base.txt" "$log/reduce-ha.txt" >&2 || true
    exit 1
}
# the blast radius must be on record: the worker prints per-site counters
# and the plan's frame drops must actually have fired
grep -Eq "^faults: .*frame-drop=[1-9]" "$log/worker-ha.txt" || {
    echo "worker reported no injected frame drops:" >&2
    grep "^faults:" "$log/worker-ha.txt" >&2 || echo "(no faults line at all)" >&2
    exit 1
}
echo "chaos-failover smoke OK (frame drops + spill faults + primary SIGKILL, reduce outputs bit-identical)"
