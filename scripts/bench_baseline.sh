#!/usr/bin/env bash
# Record a perf-trajectory snapshot: run the fig7/fig8/fig9 bench
# harnesses plus the op-dispatch microbench (bench_dispatch) once and
# write their raw output (plus host metadata) as JSON.
#
#   scripts/bench_baseline.sh [out.json]     # default: BENCH_seed.json
#
# The snapshot keeps the benches' full stdout so any row can be diffed
# across PRs; the fig7 harness degrades to CPU-only columns when AOT
# artifacts are absent (see benches/fig7_op_speedups.rs).
set -euo pipefail

cd "$(dirname "$0")/.."
out="${1:-BENCH_seed.json}"
tmpdir="$(mktemp -d)"
trap 'rm -rf "$tmpdir"' EXIT

benches=(fig7_op_speedups fig8_placement fig9_coordination bench_dispatch)
for b in "${benches[@]}"; do
    echo "=== cargo bench --bench $b ===" >&2
    (cd rust && cargo bench --locked --bench "$b") >"$tmpdir/$b.txt" 2>&1
done

python3 - "$out" "$tmpdir" "${benches[@]}" <<'EOF'
import json, pathlib, platform, subprocess, sys, datetime

out, tmpdir, *benches = sys.argv[1:]
rustc = subprocess.run(["rustc", "--version"], capture_output=True, text=True).stdout.strip()
snapshot = {
    "version": 1,
    "status": "recorded",
    "date": datetime.datetime.now(datetime.timezone.utc).isoformat(timespec="seconds"),
    "host": {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "rustc": rustc,
    },
    "benches": {
        b: pathlib.Path(tmpdir, f"{b}.txt").read_text() for b in benches
    },
}
pathlib.Path(out).write_text(json.dumps(snapshot, indent=2) + "\n")
print(f"wrote {out}")
EOF
